//! TPC-H query implementations — the analytics workloads of Figure 3.
//!
//! Each query module defines exactly one
//! [`crate::analytics::engine::PlanSpec`] (predicate expression,
//! dimension hash-join builds, group key + aggregate slots, finalizer)
//! plus an independent row-at-a-time *oracle* (`naive`), and the test
//! compares the two on generated data. Every run returns a
//! [`QueryOutput`] with [`ExecStats`] feeding the memory-contention
//! model. The serial, morsel-parallel, and distributed paths all drive
//! the same plan.

pub mod q1;
pub mod q12;
pub mod q14;
pub mod q18;
pub mod q19;
pub mod q3;
pub mod q5;
pub mod q6;
pub mod q9;

use super::ops::ExecStats;
use super::tpch::TpchDb;

/// A result cell.
#[derive(Clone, Debug)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
}

impl Value {
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Int(i) => *i as f64,
            Value::Float(f) => *f,
            Value::Str(_) => panic!("string cell"),
        }
    }

    /// Approximate equality (floats within relative 1e-9).
    pub fn approx_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (a, b) => {
                let (x, y) = (a.as_f64(), b.as_f64());
                (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0)
            }
        }
    }
}

pub type Row = Vec<Value>;

/// Output of one query execution.
#[derive(Clone, Debug, Default)]
pub struct QueryOutput {
    pub rows: Vec<Row>,
    pub stats: ExecStats,
}

impl QueryOutput {
    pub fn approx_eq_rows(&self, other: &[Row]) -> bool {
        self.rows.len() == other.len()
            && self
                .rows
                .iter()
                .zip(other.iter())
                .all(|(a, b)| a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.approx_eq(y)))
    }
}

/// Names of all implemented queries, Figure-3 order.
pub const QUERY_NAMES: [&str; 9] = ["q1", "q3", "q5", "q6", "q9", "q12", "q14", "q18", "q19"];

/// Run a query by name, single-threaded, through the unified engine.
pub fn run_query(db: &TpchDb, name: &str) -> Option<QueryOutput> {
    let spec = crate::analytics::engine::spec(name)?;
    Some(crate::analytics::engine::run_serial(db, &spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn registry_runs_all() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 3));
        for name in QUERY_NAMES {
            let out = run_query(&db, name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(out.stats.bytes_scanned > 0, "{name} reported no scan bytes");
        }
        assert!(run_query(&db, "q99").is_none());
    }

    #[test]
    fn value_approx_eq() {
        assert!(Value::Int(3).approx_eq(&Value::Int(3)));
        assert!(Value::Float(1.0).approx_eq(&Value::Float(1.0 + 1e-12)));
        assert!(!Value::Float(1.0).approx_eq(&Value::Float(1.01)));
        assert!(Value::Str("x".into()).approx_eq(&Value::Str("x".into())));
        assert!(Value::Int(2).approx_eq(&Value::Float(2.0)));
    }
}

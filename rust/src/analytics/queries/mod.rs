//! TPC-H query implementations — the analytics workloads of Figure 3.
//!
//! Each query module is a parameterized IR constructor: one `logical`
//! function producing the query's
//! [`crate::analytics::engine::LogicalPlan`] (predicate tree, dimension
//! joins, group key + aggregate slots, finalize spec) plus an
//! independent row-at-a-time *oracle* (`naive`), and the test compares
//! the two on generated data. Every run returns a [`QueryOutput`] with
//! [`ExecStats`] feeding the memory-contention model. The serial,
//! morsel-parallel, and distributed paths all drive the same plan — and
//! a worker compiles whatever IR arrives on the wire; nothing here is
//! baked into the executor.
//!
//! [`REGISTRY`] is the **single** query table: adding a query means one
//! module plus one row here — [`QUERY_NAMES`],
//! [`crate::analytics::engine::spec`], and [`build`] all derive from it.

pub mod q1;
pub mod q12;
pub mod q14;
pub mod q18;
pub mod q19;
pub mod q3;
pub mod q5;
pub mod q6;
pub mod q9;

use super::engine::plan::{LogicalPlan, PlanParams};
use super::ops::ExecStats;
use super::tpch::TpchDb;
use crate::error::Result;

/// A result cell.
#[derive(Clone, Debug)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
}

impl Value {
    pub fn as_f64(&self) -> f64 {
        match self {
            Value::Int(i) => *i as f64,
            Value::Float(f) => *f,
            Value::Str(_) => panic!("string cell"),
        }
    }

    /// Approximate equality (floats within relative 1e-9).
    pub fn approx_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Str(a), Value::Str(b)) => a == b,
            (a, b) => {
                let (x, y) = (a.as_f64(), b.as_f64());
                (x - y).abs() <= 1e-9 * x.abs().max(y.abs()).max(1.0)
            }
        }
    }
}

pub type Row = Vec<Value>;

/// Output of one query execution.
#[derive(Clone, Debug, Default)]
pub struct QueryOutput {
    pub rows: Vec<Row>,
    pub stats: ExecStats,
}

impl QueryOutput {
    pub fn approx_eq_rows(&self, other: &[Row]) -> bool {
        self.rows.len() == other.len()
            && self
                .rows
                .iter()
                .zip(other.iter())
                .all(|(a, b)| a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.approx_eq(y)))
    }
}

/// One registered query: its name and its IR constructor.
pub struct QueryDef {
    pub name: &'static str,
    /// Build the query's [`LogicalPlan`] from a parameter bag.
    pub logical: fn(&PlanParams) -> Result<LogicalPlan>,
}

/// THE query table, Figure-3 order — the one place a query is wired in.
/// [`QUERY_NAMES`], [`crate::analytics::engine::spec`], and [`build`]
/// are all views over this array.
pub const REGISTRY: [QueryDef; 9] = [
    QueryDef { name: "q1", logical: q1::logical },
    QueryDef { name: "q3", logical: q3::logical },
    QueryDef { name: "q5", logical: q5::logical },
    QueryDef { name: "q6", logical: q6::logical },
    QueryDef { name: "q9", logical: q9::logical },
    QueryDef { name: "q12", logical: q12::logical },
    QueryDef { name: "q14", logical: q14::logical },
    QueryDef { name: "q18", logical: q18::logical },
    QueryDef { name: "q19", logical: q19::logical },
];

/// Names of all implemented queries, Figure-3 order — derived from
/// [`REGISTRY`] at compile time, never a second list to keep in sync.
pub const QUERY_NAMES: [&str; REGISTRY.len()] = {
    let mut names = [""; REGISTRY.len()];
    let mut i = 0;
    while i < names.len() {
        names[i] = REGISTRY[i].name;
        i += 1;
    }
    names
};

/// Build a query's plan with `--param` overrides. Rejects unknown query
/// names and parameter keys the builder never read (typo protection).
pub fn build(name: &str, p: &PlanParams) -> Result<LogicalPlan> {
    let def = REGISTRY
        .iter()
        .find(|d| d.name == name)
        .ok_or_else(|| crate::err!("unknown query {name}"))?;
    // Per-build read tracking: a key consumed by an earlier build of a
    // reused bag must not slip past this build's stray-key check.
    p.reset_used();
    let plan = (def.logical)(p)?;
    let stray = p.unused();
    crate::ensure!(stray.is_empty(), "unknown parameter(s) for {name}: {stray:?}");
    Ok(plan)
}

/// Run a query by name, single-threaded, through the unified engine.
pub fn run_query(db: &TpchDb, name: &str) -> Option<QueryOutput> {
    let spec = crate::analytics::engine::spec(name)?;
    Some(crate::analytics::engine::run_serial(db, &spec))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn registry_runs_all() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 3));
        for name in QUERY_NAMES {
            let out = run_query(&db, name).unwrap_or_else(|| panic!("{name} missing"));
            assert!(out.stats.bytes_scanned > 0, "{name} reported no scan bytes");
        }
        assert!(run_query(&db, "q99").is_none());
    }

    #[test]
    fn registry_is_the_single_name_source() {
        assert_eq!(QUERY_NAMES.len(), REGISTRY.len());
        for (n, d) in QUERY_NAMES.iter().zip(REGISTRY.iter()) {
            assert_eq!(*n, d.name);
        }
        // Every registered builder accepts the empty parameter bag.
        for d in &REGISTRY {
            let plan = (d.logical)(&PlanParams::default()).unwrap();
            assert_eq!(plan.name, d.name);
        }
        assert!(build("q99", &PlanParams::default()).is_err());
        let mut stray = PlanParams::default();
        stray.set("not-a-knob", "1");
        assert!(build("q6", &stray).is_err(), "stray parameter must be rejected");
    }

    #[test]
    fn value_approx_eq() {
        assert!(Value::Int(3).approx_eq(&Value::Int(3)));
        assert!(Value::Float(1.0).approx_eq(&Value::Float(1.0 + 1e-12)));
        assert!(!Value::Float(1.0).approx_eq(&Value::Float(1.01)));
        assert!(Value::Str("x".into()).approx_eq(&Value::Str("x".into())));
        assert!(Value::Int(2).approx_eq(&Value::Float(2.0)));
    }
}

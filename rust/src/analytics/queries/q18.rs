//! TPC-H Q18 — large-volume customers: orders whose total quantity
//! exceeds a threshold, top-100 by order total price.
//!
//! The big-aggregation query: a full group-by over every order key —
//! the shuffle-dominant partial of the Fig. 4 analysis. In the IR it is
//! a pure gather (no predicate, no joins) whose finalize does all the
//! work: having-threshold, dense order decoration, top-k.

use crate::analytics::engine::plan::{
    kcol, vcol, FinalizeSpec, GroupsHint, LogicalPlan, OutCol, PredExpr, SortDir, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

const QTY_THRESHOLD: f64 = 300.0;
const TOP: u32 = 100;

/// The one Q18 IR constructor: no predicate, sum(quantity) grouped by
/// order key; finalize applies the quantity threshold and the top-k by
/// order total price (dense decoration through the orders table).
/// Parameter keys: `qty-threshold`, `top`.
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let threshold = p.get_f64("qty-threshold", QTY_THRESHOLD)?;
    let top = p.get_limit("top", TOP)?;
    Ok(LogicalPlan {
        name: "q18".into(),
        scan: TableRef::Lineitem,
        // Pure gather: keys and values come straight off the lineitem
        // columns; the batched HashAgg's last-key memo then collapses
        // the per-order runs (lineitem is clustered by order key).
        pred: PredExpr::True,
        joins: vec![],
        cmps: vec![],
        key: kcol("l_orderkey"),
        slots: vec![vcol("l_quantity")],
        groups_hint: GroupsHint::TableRows(TableRef::Orders),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::DimInt { table: TableRef::Orders, col: "o_custkey".into() },
                OutCol::KeyInt { shift: 0, bits: 0 },
                OutCol::DimInt { table: TableRef::Orders, col: "o_orderdate".into() },
                OutCol::DimFloat { table: TableRef::Orders, col: "o_totalprice".into() },
                OutCol::Acc(0),
            ],
            having_gt: Some((0, threshold)),
            // top_k_desc semantics: totalprice desc, orderkey asc ties.
            sort: vec![(3, SortDir::Desc), (1, SortDir::Asc)],
            limit: top,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q18 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let li = &db.lineitem;
    let mut sums: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        *sums.entry(li.col("l_orderkey").as_i64()[i]).or_insert(0.0) +=
            li.col("l_quantity").as_f64()[i];
    }
    let orders = &db.orders;
    let mut big: Vec<(i64, f64)> = sums
        .iter()
        .filter(|(_, q)| **q > QTY_THRESHOLD)
        .map(|(ok, _)| (*ok, orders.col("o_totalprice").as_f64()[(*ok - 1) as usize]))
        .collect();
    crate::analytics::ops::top_k_desc(&mut big, TOP as usize);
    big.into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(orders.col("o_custkey").as_i64()[orow]),
                Value::Int(ok),
                Value::Int(orders.col("o_orderdate").as_i32()[orow] as i64),
                Value::Float(total),
                Value::Float(sums[&ok]),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        // Larger SF so a few orders clear the 300-quantity threshold.
        let db = TpchDb::generate(TpchConfig::new(0.01, 71));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{} vs {} rows", out.rows.len(), oracle.len());
    }

    #[test]
    fn all_results_exceed_threshold() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 73));
        for r in run(&db).rows {
            assert!(r[4].as_f64() > QTY_THRESHOLD);
        }
    }

    #[test]
    fn threshold_param_is_a_having_knob() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 73));
        let strict = run(&db).rows.len();
        let mut bag = PlanParams::new();
        bag.set("qty-threshold", "250");
        let loose = engine::run_serial(&db, &logical(&bag).unwrap());
        assert!(loose.rows.len() >= strict, "lower threshold must admit more orders");
        for r in &loose.rows {
            assert!(r[4].as_f64() > 250.0);
        }
    }

    #[test]
    fn groupby_covers_every_order_with_lines() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 79));
        let out = run(&db);
        // The aggregation hash table must be sized like the order count.
        assert!(out.stats.ht_bytes > db.orders.len() as u64);
    }
}

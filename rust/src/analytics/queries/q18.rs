//! TPC-H Q18 — large-volume customers: orders whose total quantity
//! exceeds a threshold, top-100 by order total price.
//!
//! The big-aggregation query: a full group-by over every order key.

use crate::analytics::morsel::{MorselPlan, Partial, PartialFn};
use crate::analytics::ops::{ExecStats, GroupBy};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

const QTY_THRESHOLD: f64 = 300.0;
const TOP: usize = 100;

pub fn run(db: &TpchDb) -> QueryOutput {
    let mut stats = ExecStats::default();
    let li = &db.lineitem;
    let lok = li.col("l_orderkey").as_i64();
    let qty = li.col("l_quantity").as_f64();
    stats.scan(li.len(), 16);

    // sum(quantity) per order — the expensive aggregation.
    let mut g: GroupBy<1> = GroupBy::with_capacity(db.orders.len());
    for i in 0..li.len() {
        g.update(lok[i], [qty[i]]);
    }
    stats.ht_bytes += g.bytes();

    let orders = &db.orders;
    let ocust = orders.col("o_custkey").as_i64();
    let odate = orders.col("o_orderdate").as_i32();
    let ototal = orders.col("o_totalprice").as_f64();
    stats.scan(orders.len(), 20);

    let mut big: Vec<(i64, f64)> = Vec::new(); // (orderkey, totalprice)
    let mut qty_of: std::collections::HashMap<i64, f64> = Default::default();
    for (ok, s, _) in &g.groups {
        if s[0] > QTY_THRESHOLD {
            let orow = (*ok - 1) as usize;
            big.push((*ok, ototal[orow]));
            qty_of.insert(*ok, s[0]);
        }
    }
    crate::analytics::ops::top_k_desc(&mut big, TOP);
    stats.rows_out = big.len() as u64;

    let rows = big
        .into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(ocust[orow]),
                Value::Int(ok),
                Value::Int(odate[orow] as i64),
                Value::Float(total),
                Value::Float(qty_of[&ok]),
            ]
        })
        .collect();
    QueryOutput { rows, stats }
}

/// Morsel plan: the heavy one — every morsel produces a per-orderkey
/// quantity group-by (the shuffle-dominant partial of the Fig. 4
/// analysis); finalize applies the quantity threshold and the top-100.
pub(crate) fn morsel_plan() -> MorselPlan {
    MorselPlan { width: 1, prepare: morsel_prepare, finalize: morsel_finalize }
}

fn morsel_prepare<'a>(db: &'a TpchDb) -> (PartialFn<'a>, ExecStats) {
    let li = &db.lineitem;
    let lok = li.col("l_orderkey").as_i64();
    let qty = li.col("l_quantity").as_f64();
    let kernel: PartialFn<'a> = Box::new(move |lo, hi| {
        let mut st = ExecStats::default();
        st.scan(hi - lo, 16);
        let mut g: GroupBy<1> = GroupBy::with_capacity((hi - lo) / 4 + 16);
        for i in lo..hi {
            g.update(lok[i], [qty[i]]);
        }
        st.ht_bytes += g.bytes();
        Partial::from_groupby(&g, st)
    });
    (kernel, ExecStats::default())
}

fn morsel_finalize(db: &TpchDb, p: &Partial) -> Vec<Row> {
    let orders = &db.orders;
    let ocust = orders.col("o_custkey").as_i64();
    let odate = orders.col("o_orderdate").as_i32();
    let ototal = orders.col("o_totalprice").as_f64();
    let mut big: Vec<(i64, f64)> = Vec::new();
    let mut qty_of: std::collections::HashMap<i64, f64> = Default::default();
    for i in 0..p.len() {
        let q = p.acc(i)[0];
        if q > QTY_THRESHOLD {
            let ok = p.keys[i];
            big.push((ok, ototal[(ok - 1) as usize]));
            qty_of.insert(ok, q);
        }
    }
    crate::analytics::ops::top_k_desc(&mut big, TOP);
    big.into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(ocust[orow]),
                Value::Int(ok),
                Value::Int(odate[orow] as i64),
                Value::Float(total),
                Value::Float(qty_of[&ok]),
            ]
        })
        .collect()
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let li = &db.lineitem;
    let mut sums: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        *sums.entry(li.col("l_orderkey").as_i64()[i]).or_insert(0.0) +=
            li.col("l_quantity").as_f64()[i];
    }
    let orders = &db.orders;
    let mut big: Vec<(i64, f64)> = sums
        .iter()
        .filter(|(_, q)| **q > QTY_THRESHOLD)
        .map(|(ok, _)| (*ok, orders.col("o_totalprice").as_f64()[(*ok - 1) as usize]))
        .collect();
    crate::analytics::ops::top_k_desc(&mut big, TOP);
    big.into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(orders.col("o_custkey").as_i64()[orow]),
                Value::Int(ok),
                Value::Int(orders.col("o_orderdate").as_i32()[orow] as i64),
                Value::Float(total),
                Value::Float(sums[&ok]),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        // Larger SF so a few orders clear the 300-quantity threshold.
        let db = TpchDb::generate(TpchConfig::new(0.01, 71));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{} vs {} rows", out.rows.len(), oracle.len());
    }

    #[test]
    fn all_results_exceed_threshold() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 73));
        for r in run(&db).rows {
            assert!(r[4].as_f64() > QTY_THRESHOLD);
        }
    }

    #[test]
    fn groupby_covers_every_order_with_lines() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 79));
        let out = run(&db);
        // The aggregation hash table must be sized like the order count.
        assert!(out.stats.ht_bytes > db.orders.len() as u64);
    }
}

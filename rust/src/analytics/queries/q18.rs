//! TPC-H Q18 — large-volume customers: orders whose total quantity
//! exceeds a threshold, top-100 by order total price.
//!
//! The big-aggregation query: a full group-by over every order key —
//! the shuffle-dominant partial of the Fig. 4 analysis.

use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

const QTY_THRESHOLD: f64 = 300.0;
const TOP: usize = 100;

/// The one Q18 plan: no predicate, sum(quantity) grouped by order key;
/// finalize applies the quantity threshold and the top-100 by order
/// total price.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q18", width: 1, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let li = &db.lineitem;
    let lok = li.col("l_orderkey").as_i64();
    let qty = li.col("l_quantity").as_f64();
    // The finalize side reads custkey/date/totalprice for the survivors.
    stats.scan(db.orders.len(), 20);
    // Pure gather: keys and values come straight off the lineitem
    // columns; the batched HashAgg's last-key memo then collapses the
    // per-order runs (lineitem is clustered by order key).
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            out.keys.push(lok[i]);
            out.cols[0].push(qty[i]);
        });
    });
    let hint = db.orders.len();
    (Compiled { pred: Predicate::True, payload_bytes: 16, eval, groups_hint: hint }, stats)
}

fn finalize(db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let orders = &db.orders;
    let ocust = orders.col("o_custkey").as_i64();
    let odate = orders.col("o_orderdate").as_i32();
    let ototal = orders.col("o_totalprice").as_f64();
    let mut big: Vec<(i64, f64)> = Vec::new(); // (orderkey, totalprice)
    let mut qty_of: std::collections::HashMap<i64, f64> = Default::default();
    for i in 0..p.len() {
        let q = p.acc(i)[0];
        if q > QTY_THRESHOLD {
            let ok = p.keys[i];
            big.push((ok, ototal[(ok - 1) as usize]));
            qty_of.insert(ok, q);
        }
    }
    crate::analytics::ops::top_k_desc(&mut big, TOP);
    big.into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(ocust[orow]),
                Value::Int(ok),
                Value::Int(odate[orow] as i64),
                Value::Float(total),
                Value::Float(qty_of[&ok]),
            ]
        })
        .collect()
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let li = &db.lineitem;
    let mut sums: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        *sums.entry(li.col("l_orderkey").as_i64()[i]).or_insert(0.0) +=
            li.col("l_quantity").as_f64()[i];
    }
    let orders = &db.orders;
    let mut big: Vec<(i64, f64)> = sums
        .iter()
        .filter(|(_, q)| **q > QTY_THRESHOLD)
        .map(|(ok, _)| (*ok, orders.col("o_totalprice").as_f64()[(*ok - 1) as usize]))
        .collect();
    crate::analytics::ops::top_k_desc(&mut big, TOP);
    big.into_iter()
        .map(|(ok, total)| {
            let orow = (ok - 1) as usize;
            vec![
                Value::Int(orders.col("o_custkey").as_i64()[orow]),
                Value::Int(ok),
                Value::Int(orders.col("o_orderdate").as_i32()[orow] as i64),
                Value::Float(total),
                Value::Float(sums[&ok]),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        // Larger SF so a few orders clear the 300-quantity threshold.
        let db = TpchDb::generate(TpchConfig::new(0.01, 71));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{} vs {} rows", out.rows.len(), oracle.len());
    }

    #[test]
    fn all_results_exceed_threshold() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 73));
        for r in run(&db).rows {
            assert!(r[4].as_f64() > QTY_THRESHOLD);
        }
    }

    #[test]
    fn groupby_covers_every_order_with_lines() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 79));
        let out = run(&db);
        // The aggregation hash table must be sized like the order count.
        assert!(out.stats.ht_bytes > db.orders.len() as u64);
    }
}

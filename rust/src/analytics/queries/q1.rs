//! TPC-H Q1 — pricing summary report.
//!
//! Full scan of `lineitem` with a date filter and a tiny group-by
//! (returnflag × linestatus). The most memory-bandwidth-hungry query of
//! the set: it touches seven wide columns end to end.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

/// Cutoff: shipdate <= 1998-12-01 - 90 days = 1998-09-02.
fn cutoff() -> i32 {
    date_to_days(1998, 12, 1) - 90
}

/// The one Q1 plan all three execution paths drive: shipdate-window
/// predicate, (returnflag × linestatus) group key, five running sums;
/// finalize computes the averages and sorts by the flag pair.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q1", width: 5, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let tax = li.col("l_tax").as_f64();
    let rf = li.col("l_returnflag").as_u8();
    let ls = li.col("l_linestatus").as_u8();
    let pred = Predicate::i32_range(ship, i32::MIN, cutoff() + 1);
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let dp = price[i] * (1.0 - disc[i]);
            out.keys.push(((rf[i] as i64) << 8) | ls[i] as i64);
            out.cols[0].push(qty[i]);
            out.cols[1].push(price[i]);
            out.cols[2].push(dp);
            out.cols[3].push(dp * (1.0 + tax[i]));
            out.cols[4].push(disc[i]);
        });
    });
    (Compiled { pred, payload_bytes: 8 * 4 + 2, eval, groups_hint: 8 }, ExecStats::default())
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let mut rows: Vec<Row> = (0..p.len())
        .map(|gi| {
            let key = p.keys[gi];
            let s = p.acc(gi);
            let cnt = p.counts[gi];
            let c = cnt as f64;
            vec![
                Value::Str(((key >> 8) as u8 as char).to_string()),
                Value::Str(((key & 0xff) as u8 as char).to_string()),
                Value::Float(s[0]),
                Value::Float(s[1]),
                Value::Float(s[2]),
                Value::Float(s[3]),
                Value::Float(s[0] / c),
                Value::Float(s[1] / c),
                Value::Float(s[4] / c),
                Value::Int(cnt as i64),
            ]
        })
        .collect();
    rows.sort_by(|a, b| {
        let ka = (str_of(&a[0]), str_of(&a[1]));
        let kb = (str_of(&b[0]), str_of(&b[1]));
        ka.cmp(&kb)
    });
    rows
}

fn str_of(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        _ => unreachable!(),
    }
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::BTreeMap;
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let tax = li.col("l_tax").as_f64();
    let rf = li.col("l_returnflag").as_u8();
    let ls = li.col("l_linestatus").as_u8();
    let mut groups: BTreeMap<(char, char), (f64, f64, f64, f64, f64, u64)> = BTreeMap::new();
    for i in 0..li.len() {
        if ship[i] > cutoff() {
            continue;
        }
        let e = groups
            .entry((rf[i] as char, ls[i] as char))
            .or_insert((0.0, 0.0, 0.0, 0.0, 0.0, 0));
        let dp = price[i] * (1.0 - disc[i]);
        e.0 += qty[i];
        e.1 += price[i];
        e.2 += dp;
        e.3 += dp * (1.0 + tax[i]);
        e.4 += disc[i];
        e.5 += 1;
    }
    groups
        .into_iter()
        .map(|((f, s), (q, p, d, c, di, n))| {
            vec![
                Value::Str(f.to_string()),
                Value::Str(s.to_string()),
                Value::Float(q),
                Value::Float(p),
                Value::Float(d),
                Value::Float(c),
                Value::Float(q / n as f64),
                Value::Float(p / n as f64),
                Value::Float(di / n as f64),
                Value::Int(n as i64),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 11));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:?}\noracle:\n{:?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn has_expected_groups() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 5));
        let out = run(&db);
        // Groups: (A,F), (N,F), (N,O), (R,F) — the classic Q1 output.
        assert!(out.rows.len() >= 3 && out.rows.len() <= 4, "groups={}", out.rows.len());
        // Counts must sum to the number of selected rows.
        let total: i64 = out.rows.iter().map(|r| match r[9] {
            Value::Int(n) => n,
            _ => 0,
        }).sum();
        assert!(total > 0 && (total as usize) <= db.lineitem.len());
    }

    #[test]
    fn stats_reflect_full_scan() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 5));
        let out = run(&db);
        // At least the shipdate column (4 B/row) must be scanned fully.
        assert!(out.stats.bytes_scanned >= 4 * db.lineitem.len() as u64);
        assert!(out.stats.ht_bytes > 0);
    }
}

//! TPC-H Q1 — pricing summary report.
//!
//! Full scan of `lineitem` with a date filter and a tiny group-by
//! (returnflag × linestatus). The most memory-bandwidth-hungry query of
//! the set: it touches seven wide columns end to end.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    i32_range, kcol, kpack, vadd, vcol, vconst, vmul, vrevenue, FinalizeSpec, GroupsHint,
    LogicalPlan, OutCol, SortDir, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

/// Cutoff: shipdate <= 1998-12-01 - 90 days = 1998-09-02.
fn cutoff() -> i32 {
    date_to_days(1998, 12, 1) - 90
}

/// The one Q1 IR constructor: shipdate-window predicate,
/// (returnflag × linestatus) packed group key, five running sums;
/// finalize computes the averages and sorts by the flag pair.
/// Parameter key: `cutoff` (latest shipdate, inclusive).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let cut = p.get_date("cutoff", cutoff())?;
    Ok(LogicalPlan {
        name: "q1".into(),
        scan: TableRef::Lineitem,
        pred: i32_range("l_shipdate", i32::MIN, cut + 1),
        joins: vec![],
        cmps: vec![],
        key: kpack(kcol("l_returnflag"), 8, kcol("l_linestatus")),
        slots: vec![
            vcol("l_quantity"),
            vcol("l_extendedprice"),
            vrevenue(),
            vmul(vrevenue(), vadd(vconst(1.0), vcol("l_tax"))),
            vcol("l_discount"),
        ],
        groups_hint: GroupsHint::Const(8),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::KeyChar { shift: 8 },
                OutCol::KeyChar { shift: 0 },
                OutCol::Acc(0),
                OutCol::Acc(1),
                OutCol::Acc(2),
                OutCol::Acc(3),
                OutCol::AccOverCount(0),
                OutCol::AccOverCount(1),
                OutCol::AccOverCount(4),
                OutCol::Count,
            ],
            having_gt: None,
            sort: vec![(0, SortDir::Asc), (1, SortDir::Asc)],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q1 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::BTreeMap;
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let tax = li.col("l_tax").as_f64();
    let rf = li.col("l_returnflag").as_u8();
    let ls = li.col("l_linestatus").as_u8();
    let mut groups: BTreeMap<(char, char), (f64, f64, f64, f64, f64, u64)> = BTreeMap::new();
    for i in 0..li.len() {
        if ship[i] > cutoff() {
            continue;
        }
        let e = groups
            .entry((rf[i] as char, ls[i] as char))
            .or_insert((0.0, 0.0, 0.0, 0.0, 0.0, 0));
        let dp = price[i] * (1.0 - disc[i]);
        e.0 += qty[i];
        e.1 += price[i];
        e.2 += dp;
        e.3 += dp * (1.0 + tax[i]);
        e.4 += disc[i];
        e.5 += 1;
    }
    groups
        .into_iter()
        .map(|((f, s), (q, p, d, c, di, n))| {
            vec![
                Value::Str(f.to_string()),
                Value::Str(s.to_string()),
                Value::Float(q),
                Value::Float(p),
                Value::Float(d),
                Value::Float(c),
                Value::Float(q / n as f64),
                Value::Float(p / n as f64),
                Value::Float(di / n as f64),
                Value::Int(n as i64),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 11));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:?}\noracle:\n{:?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn has_expected_groups() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 5));
        let out = run(&db);
        // Groups: (A,F), (N,F), (N,O), (R,F) — the classic Q1 output.
        assert!(out.rows.len() >= 3 && out.rows.len() <= 4, "groups={}", out.rows.len());
        // Counts must sum to the number of selected rows.
        let total: i64 = out.rows.iter().map(|r| match r[9] {
            Value::Int(n) => n,
            _ => 0,
        }).sum();
        assert!(total > 0 && (total as usize) <= db.lineitem.len());
    }

    #[test]
    fn cutoff_param_narrows_the_scan() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 5));
        let full = run(&db);
        let mut bag = PlanParams::new();
        bag.set("cutoff", "1994-01-01");
        let narrowed = engine::run_serial(&db, &logical(&bag).unwrap());
        let count = |o: &QueryOutput| -> i64 {
            o.rows
                .iter()
                .map(|r| match r[9] {
                    Value::Int(n) => n,
                    _ => 0,
                })
                .sum()
        };
        assert!(count(&narrowed) < count(&full), "earlier cutoff must drop rows");
    }

    #[test]
    fn stats_reflect_full_scan() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 5));
        let out = run(&db);
        // At least the shipdate column (4 B/row) must be scanned fully.
        assert!(out.stats.bytes_scanned >= 4 * db.lineitem.len() as u64);
        assert!(out.stats.ht_bytes > 0);
    }
}

//! TPC-H Q3 — shipping priority: top-10 unshipped orders by revenue.
//!
//! customer(BUILDING) ⋈ orders(before date) ⋈ lineitem(after date),
//! revenue grouped by order. Exercises two hash joins and a top-k.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{
    self, BatchEval, Compiled, EvalBatch, HashJoinTable, PlanSpec, Predicate, Sel,
};
use crate::analytics::ops::{all_rows, filter_code_eq, filter_i32_range, top_k_desc, ExecStats};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

fn pivot() -> i32 {
    date_to_days(1995, 3, 15)
}

/// The one Q3 plan: the customer semi-join and the order hash table are
/// built once at compile time (broadcast side); the kernel probes orders
/// per lineitem and sums revenue per order key. Finalize takes the
/// top-10 and resolves order dates through the dense orderkey index.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q3", width: 1, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let pivot = pivot();

    // customer: mktsegment = 'BUILDING'.
    let cust = &db.customer;
    let (_, seg_codes) = cust.col("c_mktsegment").as_str_codes();
    stats.scan(cust.len(), 4);
    let cust_sel = match cust.col("c_mktsegment").dict_code("BUILDING") {
        Some(c) => filter_code_eq(&all_rows(cust.len()), seg_codes, c),
        None => Vec::new(),
    };
    let custkeys = cust.col("c_custkey").as_i64();
    stats.scan(cust_sel.len(), 8);
    let cust_map = HashJoinTable::build_dim(custkeys, &cust_sel, &mut stats);

    // orders: o_orderdate < pivot, semi-joined to BUILDING customers.
    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    let ocust = orders.col("o_custkey").as_i64();
    stats.scan(orders.len(), 4);
    let ord_sel: Vec<u32> = filter_i32_range(&all_rows(orders.len()), odate, i32::MIN, pivot)
        .into_iter()
        .filter(|&o| cust_map.probe_first(ocust[o as usize]).is_some())
        .collect();
    stats.scan(ord_sel.len(), 8);
    let okeys = orders.col("o_orderkey").as_i64();
    let ord_map = HashJoinTable::build_dim(okeys, &ord_sel, &mut stats);

    // lineitem: l_shipdate > pivot, joined to surviving orders.
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let lok = li.col("l_orderkey").as_i64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let pred = Predicate::i32_range(ship, pivot + 1, i32::MAX);
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            if ord_map.probe_first(lok[i]).is_some() {
                out.keys.push(lok[i]);
                out.cols[0].push(price[i] * (1.0 - disc[i]));
            }
        });
    });
    (Compiled { pred, payload_bytes: 8 * 3, eval, groups_hint: 256 }, stats)
}

fn finalize(db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let odate = db.orders.col("o_orderdate").as_i32();
    let mut items: Vec<(i64, f64)> = (0..p.len()).map(|i| (p.keys[i], p.acc(i)[0])).collect();
    top_k_desc(&mut items, 10);
    items
        .into_iter()
        .map(|(k, rev)| {
            // orderkey is dense 1..=N → direct date lookup.
            vec![Value::Int(k), Value::Float(rev), Value::Int(odate[(k - 1) as usize] as i64)]
        })
        .collect()
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::{HashMap, HashSet};
    let pivot = pivot();
    let cust = &db.customer;
    let mut building: HashSet<i64> = HashSet::new();
    for i in 0..cust.len() {
        if cust.col("c_mktsegment").str_at(i) == "BUILDING" {
            building.insert(cust.col("c_custkey").as_i64()[i]);
        }
    }
    let orders = &db.orders;
    let mut valid_orders: HashMap<i64, i32> = HashMap::new();
    for i in 0..orders.len() {
        let d = orders.col("o_orderdate").as_i32()[i];
        if d < pivot && building.contains(&orders.col("o_custkey").as_i64()[i]) {
            valid_orders.insert(orders.col("o_orderkey").as_i64()[i], d);
        }
    }
    let li = &db.lineitem;
    let mut revenue: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        if li.col("l_shipdate").as_i32()[i] > pivot {
            let ok = li.col("l_orderkey").as_i64()[i];
            if valid_orders.contains_key(&ok) {
                *revenue.entry(ok).or_insert(0.0) += li.col("l_extendedprice").as_f64()[i]
                    * (1.0 - li.col("l_discount").as_f64()[i]);
            }
        }
    }
    let mut items: Vec<(i64, f64)> = revenue.into_iter().collect();
    top_k_desc(&mut items, 10);
    items
        .into_iter()
        .map(|(k, r)| vec![Value::Int(k), Value::Float(r), Value::Int(valid_orders[&k] as i64)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:#?}\noracle:\n{:#?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn at_most_ten_rows_sorted_desc() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 19));
        let out = run(&db);
        assert!(out.rows.len() <= 10);
        let revs: Vec<f64> = out.rows.iter().map(|r| r[1].as_f64()).collect();
        for w in revs.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn join_stats_recorded() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        assert!(out.stats.ht_bytes > 0);
        assert!(out.stats.bytes_scanned > 0);
    }
}

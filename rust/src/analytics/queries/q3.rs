//! TPC-H Q3 — shipping priority: top-10 unshipped orders by revenue.
//!
//! customer(segment) ⋈ orders(before date) ⋈ lineitem(after date),
//! revenue grouped by order. Exercises the IR's chained dimension
//! builds (orders link into the customer semi-join) and a top-k with
//! dense date decoration.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    i32_range, kcol, str_eq, vrevenue, FinalizeSpec, GroupsHint, JoinStep, KeyCols, LinkRef,
    LogicalPlan, OutCol, SortDir, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::ops::top_k_desc;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

fn pivot() -> i32 {
    date_to_days(1995, 3, 15)
}

const SEGMENT: &str = "BUILDING";
const TOP: u32 = 10;

/// The one Q3 IR constructor: the customer semi-join is a link-only
/// step; orders build over it; the kernel probes orders per lineitem and
/// sums revenue per order key. Finalize takes the top-k and resolves
/// order dates through the dense orderkey index. Parameter keys:
/// `segment` (market segment), `pivot` (date), `top` (result rows).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let segment = p.get_str("segment", SEGMENT)?;
    let pivot = p.get_date("pivot", pivot())?;
    let top = p.get_limit("top", TOP)?;
    Ok(LogicalPlan {
        name: "q3".into(),
        scan: TableRef::Lineitem,
        pred: i32_range("l_shipdate", pivot + 1, i32::MAX),
        joins: vec![
            JoinStep {
                table: TableRef::Customer,
                dense: false,
                build_key: Some(KeyCols::Col("c_custkey".into())),
                probe_key: None,
                filter: str_eq("c_mktsegment", &segment),
                link: None,
                payloads: vec![],
            },
            JoinStep {
                table: TableRef::Orders,
                dense: false,
                build_key: Some(KeyCols::Col("o_orderkey".into())),
                probe_key: Some(KeyCols::Col("l_orderkey".into())),
                filter: i32_range("o_orderdate", i32::MIN, pivot),
                link: Some(LinkRef { step: 0, via: "o_custkey".into() }),
                payloads: vec![],
            },
        ],
        cmps: vec![],
        key: kcol("l_orderkey"),
        slots: vec![vrevenue()],
        groups_hint: GroupsHint::Const(256),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::KeyInt { shift: 0, bits: 0 },
                OutCol::Acc(0),
                OutCol::DimInt { table: TableRef::Orders, col: "o_orderdate".into() },
            ],
            having_gt: None,
            // top_k_desc semantics: revenue desc, orderkey asc on ties.
            sort: vec![(1, SortDir::Desc), (0, SortDir::Asc)],
            limit: top,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q3 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::{HashMap, HashSet};
    let pivot = pivot();
    let cust = &db.customer;
    let mut building: HashSet<i64> = HashSet::new();
    for i in 0..cust.len() {
        if cust.col("c_mktsegment").str_at(i) == SEGMENT {
            building.insert(cust.col("c_custkey").as_i64()[i]);
        }
    }
    let orders = &db.orders;
    let mut valid_orders: HashMap<i64, i32> = HashMap::new();
    for i in 0..orders.len() {
        let d = orders.col("o_orderdate").as_i32()[i];
        if d < pivot && building.contains(&orders.col("o_custkey").as_i64()[i]) {
            valid_orders.insert(orders.col("o_orderkey").as_i64()[i], d);
        }
    }
    let li = &db.lineitem;
    let mut revenue: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        if li.col("l_shipdate").as_i32()[i] > pivot {
            let ok = li.col("l_orderkey").as_i64()[i];
            if valid_orders.contains_key(&ok) {
                *revenue.entry(ok).or_insert(0.0) += li.col("l_extendedprice").as_f64()[i]
                    * (1.0 - li.col("l_discount").as_f64()[i]);
            }
        }
    }
    let mut items: Vec<(i64, f64)> = revenue.into_iter().collect();
    top_k_desc(&mut items, TOP as usize);
    items
        .into_iter()
        .map(|(k, r)| vec![Value::Int(k), Value::Float(r), Value::Int(valid_orders[&k] as i64)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:#?}\noracle:\n{:#?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn at_most_ten_rows_sorted_desc() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 19));
        let out = run(&db);
        assert!(out.rows.len() <= 10);
        let revs: Vec<f64> = out.rows.iter().map(|r| r[1].as_f64()).collect();
        for w in revs.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn segment_and_top_params() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 19));
        let mut bag = PlanParams::new();
        bag.set("top", "3");
        bag.set("segment", "MACHINERY");
        let out = engine::run_serial(&db, &logical(&bag).unwrap());
        assert!(out.rows.len() <= 3);
    }

    #[test]
    fn join_stats_recorded() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        assert!(out.stats.ht_bytes > 0);
        assert!(out.stats.bytes_scanned > 0);
    }
}

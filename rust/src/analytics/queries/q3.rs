//! TPC-H Q3 — shipping priority: top-10 unshipped orders by revenue.
//!
//! customer(BUILDING) ⋈ orders(before date) ⋈ lineitem(after date),
//! revenue grouped by order. Exercises two hash joins and a top-k.

use crate::analytics::column::date_to_days;
use crate::analytics::morsel::{MorselPlan, Partial, PartialFn};
use crate::analytics::ops::{all_rows, filter_code_eq, filter_i32_range, top_k_desc, ExecStats, GroupBy, JoinMap};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

fn pivot() -> i32 {
    date_to_days(1995, 3, 15)
}

pub fn run(db: &TpchDb) -> QueryOutput {
    let mut stats = ExecStats::default();
    let pivot = pivot();

    // customer: mktsegment = 'BUILDING'
    let cust = &db.customer;
    let (_, seg_codes) = cust.col("c_mktsegment").as_str_codes();
    stats.scan(cust.len(), 4);
    let building = match cust.col("c_mktsegment").dict_code("BUILDING") {
        Some(c) => c,
        None => return QueryOutput::default(),
    };
    let cust_sel = filter_code_eq(&all_rows(cust.len()), seg_codes, building);
    let custkeys = cust.col("c_custkey").as_i64();
    stats.scan(cust_sel.len(), 8);

    // orders: o_orderdate < pivot, semi-joined to BUILDING customers.
    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    stats.scan(orders.len(), 4);
    let ord_sel = filter_i32_range(&all_rows(orders.len()), odate, i32::MIN, pivot);
    let ocust = orders.col("o_custkey").as_i64();
    stats.scan(ord_sel.len(), 8);
    let cust_map = JoinMap::build(custkeys, &cust_sel);
    stats.ht_bytes += cust_map.bytes();
    let ord_sel: Vec<u32> = ord_sel
        .into_iter()
        .filter(|&o| cust_map.probe_first(ocust[o as usize]).is_some())
        .collect();

    // lineitem: l_shipdate > pivot, joined to surviving orders.
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    stats.scan(li.len(), 4);
    let li_sel = filter_i32_range(&all_rows(li.len()), ship, pivot + 1, i32::MAX);
    let lok = li.col("l_orderkey").as_i64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    stats.scan(li_sel.len(), 8 * 3);

    let okeys = orders.col("o_orderkey").as_i64();
    let ord_map = JoinMap::build(okeys, &ord_sel);
    stats.ht_bytes += ord_map.bytes();

    let mut g: GroupBy<1> = GroupBy::with_capacity(1024);
    let mut order_date: Vec<i32> = Vec::new();
    for &l in &li_sel {
        let key = lok[l as usize];
        if let Some(orow) = ord_map.probe_first(key) {
            let gi = g.group_index(key);
            if gi == order_date.len() {
                order_date.push(odate[orow as usize]);
            }
            let li_us = l as usize;
            g.groups[gi].1[0] += price[li_us] * (1.0 - disc[li_us]);
            g.groups[gi].2 += 1;
        }
    }
    stats.ht_bytes += g.bytes();

    let mut items: Vec<(i64, f64)> = g.groups.iter().map(|(k, s, _)| (*k, s[0])).collect();
    let dates: std::collections::HashMap<i64, i32> = g
        .groups
        .iter()
        .zip(order_date.iter())
        .map(|((k, _, _), d)| (*k, *d))
        .collect();
    top_k_desc(&mut items, 10);
    stats.rows_out = items.len() as u64;

    let rows = items
        .into_iter()
        .map(|(k, rev)| {
            vec![Value::Int(k), Value::Float(rev), Value::Int(dates[&k] as i64)]
        })
        .collect();
    QueryOutput { rows, stats }
}

/// Morsel plan: the customer semi-join and the order hash map are built
/// once over the broadcast tables; morsels probe orders per lineitem and
/// sum revenue per order key. Finalize takes the top-10 and resolves
/// order dates through the dense orderkey index.
pub(crate) fn morsel_plan() -> MorselPlan {
    MorselPlan { width: 1, prepare: morsel_prepare, finalize: morsel_finalize }
}

fn morsel_prepare<'a>(db: &'a TpchDb) -> (PartialFn<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let pivot = pivot();

    let cust = &db.customer;
    let (_, seg_codes) = cust.col("c_mktsegment").as_str_codes();
    stats.scan(cust.len(), 4);
    let cust_sel = match cust.col("c_mktsegment").dict_code("BUILDING") {
        Some(c) => filter_code_eq(&all_rows(cust.len()), seg_codes, c),
        None => Vec::new(),
    };
    let custkeys = cust.col("c_custkey").as_i64();
    stats.scan(cust_sel.len(), 8);
    let cust_map = JoinMap::build(custkeys, &cust_sel);
    stats.ht_bytes += cust_map.bytes();

    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    let ocust = orders.col("o_custkey").as_i64();
    stats.scan(orders.len(), 4);
    let ord_sel: Vec<u32> = filter_i32_range(&all_rows(orders.len()), odate, i32::MIN, pivot)
        .into_iter()
        .filter(|&o| cust_map.probe_first(ocust[o as usize]).is_some())
        .collect();
    stats.scan(ord_sel.len(), 8);
    let okeys = orders.col("o_orderkey").as_i64();
    let ord_map = JoinMap::build(okeys, &ord_sel);
    stats.ht_bytes += ord_map.bytes();

    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let lok = li.col("l_orderkey").as_i64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let kernel: PartialFn<'a> = Box::new(move |lo, hi| {
        let mut st = ExecStats::default();
        st.scan(hi - lo, 4 + 8 * 3);
        let mut g: GroupBy<1> = GroupBy::with_capacity(256);
        for i in lo..hi {
            if ship[i] > pivot && ord_map.probe_first(lok[i]).is_some() {
                g.update(lok[i], [price[i] * (1.0 - disc[i])]);
            }
        }
        st.ht_bytes += g.bytes();
        st.rows_out += g.groups.len() as u64;
        Partial::from_groupby(&g, st)
    });
    (kernel, stats)
}

fn morsel_finalize(db: &TpchDb, p: &Partial) -> Vec<Row> {
    let odate = db.orders.col("o_orderdate").as_i32();
    let mut items: Vec<(i64, f64)> = (0..p.len()).map(|i| (p.keys[i], p.acc(i)[0])).collect();
    top_k_desc(&mut items, 10);
    items
        .into_iter()
        .map(|(k, rev)| {
            vec![Value::Int(k), Value::Float(rev), Value::Int(odate[(k - 1) as usize] as i64)]
        })
        .collect()
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::{HashMap, HashSet};
    let pivot = pivot();
    let cust = &db.customer;
    let mut building: HashSet<i64> = HashSet::new();
    for i in 0..cust.len() {
        if cust.col("c_mktsegment").str_at(i) == "BUILDING" {
            building.insert(cust.col("c_custkey").as_i64()[i]);
        }
    }
    let orders = &db.orders;
    let mut valid_orders: HashMap<i64, i32> = HashMap::new();
    for i in 0..orders.len() {
        let d = orders.col("o_orderdate").as_i32()[i];
        if d < pivot && building.contains(&orders.col("o_custkey").as_i64()[i]) {
            valid_orders.insert(orders.col("o_orderkey").as_i64()[i], d);
        }
    }
    let li = &db.lineitem;
    let mut revenue: HashMap<i64, f64> = HashMap::new();
    for i in 0..li.len() {
        if li.col("l_shipdate").as_i32()[i] > pivot {
            let ok = li.col("l_orderkey").as_i64()[i];
            if valid_orders.contains_key(&ok) {
                *revenue.entry(ok).or_insert(0.0) += li.col("l_extendedprice").as_f64()[i]
                    * (1.0 - li.col("l_discount").as_f64()[i]);
            }
        }
    }
    let mut items: Vec<(i64, f64)> = revenue.into_iter().collect();
    top_k_desc(&mut items, 10);
    items
        .into_iter()
        .map(|(k, r)| vec![Value::Int(k), Value::Float(r), Value::Int(valid_orders[&k] as i64)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized:\n{:#?}\noracle:\n{:#?}",
            out.rows,
            oracle
        );
    }

    #[test]
    fn at_most_ten_rows_sorted_desc() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 19));
        let out = run(&db);
        assert!(out.rows.len() <= 10);
        let revs: Vec<f64> = out.rows.iter().map(|r| r[1].as_f64()).collect();
        for w in revs.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn join_stats_recorded() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 17));
        let out = run(&db);
        assert!(out.stats.ht_bytes > 0);
        assert!(out.stats.bytes_scanned > 0);
    }
}

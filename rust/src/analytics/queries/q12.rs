//! TPC-H Q12 — shipping modes and order priority.
//!
//! Lineitem date-consistency filters + shipmode IN-list, joined to orders
//! (dense, with a priority-class Flag payload), counting high/low-priority
//! orders per mode.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    i32_col_lt, i32_range, kcol, pand, str_in, vconst, vpay, vsub, FinalizeSpec, GroupsHint,
    JoinStep, KeyCols, LogicalPlan, OutCol, Payload, PredExpr, SortDir, StrMatch, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

const MODES: [&str; 2] = ["MAIL", "SHIP"];
const HIGH: [&str; 2] = ["1-URGENT", "2-HIGH"];

fn window() -> (i32, i32) {
    (date_to_days(1994, 1, 1), date_to_days(1995, 1, 1))
}

fn is_high(priority: &str) -> bool {
    HIGH.contains(&priority)
}

/// The one Q12 IR constructor: mode IN-list + receipt window +
/// date-consistency predicate cascade; the dense orders step flows a
/// high-priority flag payload; finalize resolves mode codes through the
/// lineitem dictionary. Parameter keys: `modes` (comma list),
/// `date-lo`/`date-hi` (receipt window).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let modes = p.get_list("modes", &MODES)?;
    let (lo_d, hi_d) = window();
    let lo_d = p.get_date("date-lo", lo_d)?;
    let hi_d = p.get_date("date-hi", hi_d)?;
    Ok(LogicalPlan {
        name: "q12".into(),
        scan: TableRef::Lineitem,
        pred: pand(vec![
            str_in("l_shipmode", &modes),
            i32_range("l_receiptdate", lo_d, hi_d),
            i32_col_lt("l_commitdate", "l_receiptdate"),
            i32_col_lt("l_shipdate", "l_commitdate"),
        ]),
        joins: vec![JoinStep {
            table: TableRef::Orders,
            dense: true,
            build_key: None,
            probe_key: Some(KeyCols::Col("l_orderkey".into())),
            filter: PredExpr::True,
            link: None,
            payloads: vec![Payload::Flag {
                col: "o_orderpriority".into(),
                m: StrMatch::OneOf(HIGH.iter().map(|s| s.to_string()).collect()),
            }],
        }],
        cmps: vec![],
        key: kcol("l_shipmode"),
        slots: vec![vpay(0, 0), vsub(vconst(1.0), vpay(0, 0))],
        groups_hint: GroupsHint::Const(8),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::KeyDict { table: TableRef::Lineitem, col: "l_shipmode".into() },
                OutCol::AccInt(0),
                OutCol::AccInt(1),
            ],
            having_gt: None,
            sort: vec![(0, SortDir::Asc)],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q12 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let (lo, hi) = window();
    let li = &db.lineitem;
    let orders = &db.orders;
    let mut counts: std::collections::BTreeMap<String, (i64, i64)> = Default::default();
    for i in 0..li.len() {
        let mode = li.col("l_shipmode").str_at(i);
        if !MODES.contains(&mode) {
            continue;
        }
        let r = li.col("l_receiptdate").as_i32()[i];
        let c = li.col("l_commitdate").as_i32()[i];
        let s = li.col("l_shipdate").as_i32()[i];
        if !(r >= lo && r < hi && c < r && s < c) {
            continue;
        }
        let ok = li.col("l_orderkey").as_i64()[i];
        let prio = orders.col("o_orderpriority").str_at((ok - 1) as usize);
        let e = counts.entry(mode.to_string()).or_insert((0, 0));
        if is_high(prio) {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }
    counts
        .into_iter()
        .map(|(m, (h, l))| vec![Value::Str(m), Value::Int(h), Value::Int(l)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 47));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(out.approx_eq_rows(&oracle), "{:?} vs {oracle:?}", out.rows);
    }

    #[test]
    fn only_target_modes() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 53));
        for r in run(&db).rows {
            match &r[0] {
                Value::Str(m) => assert!(MODES.contains(&m.as_str())),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn modes_param_widens_the_in_list() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 53));
        let mut bag = PlanParams::new();
        bag.set("modes", "MAIL,SHIP,AIR,RAIL");
        let out = engine::run_serial(&db, &logical(&bag).unwrap());
        assert!(out.rows.len() >= run(&db).rows.len());
        for r in &out.rows {
            match &r[0] {
                Value::Str(m) => assert!(
                    ["MAIL", "SHIP", "AIR", "RAIL"].contains(&m.as_str()),
                    "unexpected mode {m}"
                ),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn counts_nonnegative() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 59));
        for r in run(&db).rows {
            assert!(matches!(r[1], Value::Int(h) if h >= 0));
            assert!(matches!(r[2], Value::Int(l) if l >= 0));
        }
    }
}

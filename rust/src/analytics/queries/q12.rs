//! TPC-H Q12 — shipping modes and order priority.
//!
//! Lineitem date-consistency filters + shipmode IN-list, joined to orders,
//! counting high/low-priority orders per mode.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

const MODES: [&str; 2] = ["MAIL", "SHIP"];

fn window() -> (i32, i32) {
    (date_to_days(1994, 1, 1), date_to_days(1995, 1, 1))
}

fn is_high(priority: &str) -> bool {
    priority == "1-URGENT" || priority == "2-HIGH"
}

/// The one Q12 plan: mode IN-list + receipt window + date-consistency
/// predicate cascade, counting high/low-priority lines per ship-mode
/// dictionary code; finalize resolves codes to mode strings and sorts.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q12", width: 2, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let (lo_d, hi_d) = window();
    let li = &db.lineitem;

    let (_, mode_codes) = li.col("l_shipmode").as_str_codes();
    let ship = li.col("l_shipdate").as_i32();
    let commit = li.col("l_commitdate").as_i32();
    let receipt = li.col("l_receiptdate").as_i32();
    let lok = li.col("l_orderkey").as_i64();
    let pred = Predicate::and(vec![
        Predicate::code_matches(li.col("l_shipmode"), |m| MODES.contains(&m)),
        Predicate::i32_range(receipt, lo_d, hi_d),
        Predicate::i32_col_lt(commit, receipt),
        Predicate::i32_col_lt(ship, commit),
    ]);

    // orders side: priority via dense orderkey index.
    let (prio_dict, prio_codes) = db.orders.col("o_orderpriority").as_str_codes();
    let high_code: Vec<bool> = prio_dict.iter().map(|p| is_high(p)).collect();
    stats.scan(db.orders.len(), 4);

    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let orow = (lok[i] - 1) as usize;
            let high = high_code[prio_codes[orow] as usize] as u8 as f64;
            out.keys.push(mode_codes[i] as i64);
            out.cols[0].push(high);
            out.cols[1].push(1.0 - high);
        });
    });
    (Compiled { pred, payload_bytes: 12, eval, groups_hint: 8 }, stats)
}

fn finalize(db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let (mode_dict, _) = db.lineitem.col("l_shipmode").as_str_codes();
    let mut rows: Vec<Row> = (0..p.len())
        .map(|i| {
            let a = p.acc(i);
            vec![
                Value::Str(mode_dict[p.keys[i] as usize].clone()),
                Value::Int(a[0] as i64),
                Value::Int(a[1] as i64),
            ]
        })
        .collect();
    rows.sort_by(|a, b| match (&a[0], &b[0]) {
        (Value::Str(x), Value::Str(y)) => x.cmp(y),
        _ => unreachable!(),
    });
    rows
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let (lo, hi) = window();
    let li = &db.lineitem;
    let orders = &db.orders;
    let mut counts: std::collections::BTreeMap<String, (i64, i64)> = Default::default();
    for i in 0..li.len() {
        let mode = li.col("l_shipmode").str_at(i);
        if !MODES.contains(&mode) {
            continue;
        }
        let r = li.col("l_receiptdate").as_i32()[i];
        let c = li.col("l_commitdate").as_i32()[i];
        let s = li.col("l_shipdate").as_i32()[i];
        if !(r >= lo && r < hi && c < r && s < c) {
            continue;
        }
        let ok = li.col("l_orderkey").as_i64()[i];
        let prio = orders.col("o_orderpriority").str_at((ok - 1) as usize);
        let e = counts.entry(mode.to_string()).or_insert((0, 0));
        if is_high(prio) {
            e.0 += 1;
        } else {
            e.1 += 1;
        }
    }
    counts
        .into_iter()
        .map(|(m, (h, l))| vec![Value::Str(m), Value::Int(h), Value::Int(l)])
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 47));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty());
        assert!(out.approx_eq_rows(&oracle), "{:?} vs {oracle:?}", out.rows);
    }

    #[test]
    fn only_target_modes() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 53));
        for r in run(&db).rows {
            match &r[0] {
                Value::Str(m) => assert!(MODES.contains(&m.as_str())),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn counts_nonnegative() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 59));
        for r in run(&db).rows {
            assert!(matches!(r[1], Value::Int(h) if h >= 0));
            assert!(matches!(r[2], Value::Int(l) if l >= 0));
        }
    }
}

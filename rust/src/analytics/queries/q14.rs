//! TPC-H Q14 — promotion effect: share of revenue from PROMO parts in a
//! one-month shipping window.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

fn window() -> (i32, i32) {
    (date_to_days(1995, 9, 1), date_to_days(1995, 10, 1))
}

/// The one Q14 plan: ship-window predicate, promo and total revenue
/// accumulators; finalize computes the percentage from the two merged
/// sums.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q14", width: 2, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let (lo_d, hi_d) = window();
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let lpk = li.col("l_partkey").as_i64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();

    let part = &db.part;
    let (type_dict, type_codes) = part.col("p_type").as_str_codes();
    let promo: Vec<bool> = type_dict.iter().map(|t| t.starts_with("PROMO")).collect();
    stats.scan(part.len(), 4);

    let pred = Predicate::i32_range(ship, lo_d, hi_d);
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let rev = price[i] * (1.0 - disc[i]);
            // partkey is dense 1..=N → direct index instead of a hash join.
            let prow = (lpk[i] - 1) as usize;
            let is_promo = promo[type_codes[prow] as usize] as u8 as f64;
            out.keys.push(0);
            out.cols[0].push(is_promo * rev);
            out.cols[1].push(rev);
        });
    });
    (Compiled { pred, payload_bytes: 24, eval, groups_hint: 1 }, stats)
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let (promo_rev, total_rev) = if p.is_empty() {
        (0.0, 0.0)
    } else {
        let a = p.acc(0);
        (a[0], a[1])
    };
    let pct = if total_rev > 0.0 { 100.0 * promo_rev / total_rev } else { 0.0 };
    vec![vec![Value::Float(pct)]]
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let (lo, hi) = window();
    let li = &db.lineitem;
    let part = &db.part;
    let mut promo = 0.0;
    let mut total = 0.0;
    for i in 0..li.len() {
        let s = li.col("l_shipdate").as_i32()[i];
        if s < lo || s >= hi {
            continue;
        }
        let rev = li.col("l_extendedprice").as_f64()[i] * (1.0 - li.col("l_discount").as_f64()[i]);
        total += rev;
        let pk = li.col("l_partkey").as_i64()[i];
        if part.col("p_type").str_at((pk - 1) as usize).starts_with("PROMO") {
            promo += rev;
        }
    }
    vec![vec![Value::Float(if total > 0.0 { 100.0 * promo / total } else { 0.0 })]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 61));
        let out = run(&db);
        assert!(out.approx_eq_rows(&naive(&db)));
    }

    #[test]
    fn percentage_in_range() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 67));
        let pct = run(&db).rows[0][0].as_f64();
        assert!((0.0..=100.0).contains(&pct), "pct={pct}");
        // PROMO is 1 of 6 type prefixes → expect roughly 1/6 ± slack.
        assert!(pct > 5.0 && pct < 35.0, "pct={pct}");
    }
}

//! TPC-H Q14 — promotion effect: share of revenue from PROMO parts in a
//! one-month shipping window.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    i32_range, kconst, vmul, vpay, vrevenue, FinalizeSpec, GroupsHint, JoinStep, KeyCols,
    LogicalPlan, OutCol, Payload, PredExpr, StrMatch, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

fn window() -> (i32, i32) {
    (date_to_days(1995, 9, 1), date_to_days(1995, 10, 1))
}

/// The one Q14 IR constructor: ship-window predicate; the dense part
/// step flows a PROMO flag payload into promo and total revenue
/// accumulators; finalize computes the percentage from the two merged
/// sums. Parameter keys: `date-lo`/`date-hi` (ship window).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let (lo_d, hi_d) = window();
    let lo_d = p.get_date("date-lo", lo_d)?;
    let hi_d = p.get_date("date-hi", hi_d)?;
    Ok(LogicalPlan {
        name: "q14".into(),
        scan: TableRef::Lineitem,
        pred: i32_range("l_shipdate", lo_d, hi_d),
        joins: vec![JoinStep {
            // partkey is dense 1..=N → direct index instead of a hash
            // join.
            table: TableRef::Part,
            dense: true,
            build_key: None,
            probe_key: Some(KeyCols::Col("l_partkey".into())),
            filter: PredExpr::True,
            link: None,
            payloads: vec![Payload::Flag {
                col: "p_type".into(),
                m: StrMatch::Prefix("PROMO".into()),
            }],
        }],
        cmps: vec![],
        key: kconst(0),
        slots: vec![vmul(vpay(0, 0), vrevenue()), vrevenue()],
        groups_hint: GroupsHint::Const(1),
        finalize: FinalizeSpec {
            scalar: true,
            columns: vec![OutCol::AccRatioPct(0, 1)],
            having_gt: None,
            sort: vec![],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q14 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let (lo, hi) = window();
    let li = &db.lineitem;
    let part = &db.part;
    let mut promo = 0.0;
    let mut total = 0.0;
    for i in 0..li.len() {
        let s = li.col("l_shipdate").as_i32()[i];
        if s < lo || s >= hi {
            continue;
        }
        let rev = li.col("l_extendedprice").as_f64()[i] * (1.0 - li.col("l_discount").as_f64()[i]);
        total += rev;
        let pk = li.col("l_partkey").as_i64()[i];
        if part.col("p_type").str_at((pk - 1) as usize).starts_with("PROMO") {
            promo += rev;
        }
    }
    vec![vec![Value::Float(if total > 0.0 { 100.0 * promo / total } else { 0.0 })]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 61));
        let out = run(&db);
        assert!(out.approx_eq_rows(&naive(&db)));
    }

    #[test]
    fn percentage_in_range() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 67));
        let pct = run(&db).rows[0][0].as_f64();
        assert!((0.0..=100.0).contains(&pct), "pct={pct}");
        // PROMO is 1 of 6 type prefixes → expect roughly 1/6 ± slack.
        assert!(pct > 5.0 && pct < 35.0, "pct={pct}");
    }

    #[test]
    fn window_param_moves_the_month() {
        let db = TpchDb::generate(TpchConfig::new(0.004, 67));
        let mut bag = PlanParams::new();
        bag.set("date-lo", "1994-03-01");
        bag.set("date-hi", "1994-04-01");
        let pct = engine::run_serial(&db, &logical(&bag).unwrap()).rows[0][0].as_f64();
        assert!((0.0..=100.0).contains(&pct), "pct={pct}");
    }
}

//! TPC-H Q9 — product-type profit measure: profit by nation and year for
//! parts whose name contains a color word.
//!
//! The widest join tree we implement (part ⋈ partsupp ⋈ lineitem ⋈
//! supplier ⋈ orders) — in the IR: a part semi-join, a packed-composite
//! partsupp probe, a supplier payload, and a dense orders step whose
//! date payload feeds a `Year` group-key expression.

use crate::analytics::column::days_to_date;
use crate::analytics::engine::plan::{
    kpack, kpay, kyear, str_contains, vcol, vmul, vpay, vrevenue, vsub, FinalizeSpec,
    GroupsHint, JoinStep, KeyCols, LogicalPlan, OutCol, Payload, PredExpr, SortDir, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::{TpchDb, NATIONS};
use crate::error::Result;

const COLOR: &str = "green";

/// Bits of the composite (partkey, suppkey) key reserved for suppkey.
/// Safe while suppkey < 2^21 (asserted at generated scale in tests).
const PS_SHIFT: u8 = 21;

/// Composite (partkey, suppkey) → i64 key, mirroring the IR's
/// `KeyCols::Packed { shift: PS_SHIFT }` (oracle-side).
#[inline]
fn ps_key(partkey: i64, suppkey: i64) -> i64 {
    (partkey << PS_SHIFT) | suppkey
}

/// The one Q9 IR constructor. Parameter key: `color` (part-name
/// substring).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let color = p.get_str("color", COLOR)?;
    Ok(LogicalPlan {
        name: "q9".into(),
        scan: TableRef::Lineitem,
        pred: PredExpr::True,
        joins: vec![
            // Parts with the color word: existence-only semi-join.
            JoinStep {
                table: TableRef::Part,
                dense: false,
                build_key: Some(KeyCols::Col("p_partkey".into())),
                probe_key: Some(KeyCols::Col("l_partkey".into())),
                filter: str_contains("p_name", &color),
                link: None,
                payloads: vec![],
            },
            // Composite partsupp index → supplycost.
            JoinStep {
                table: TableRef::Partsupp,
                dense: false,
                build_key: Some(KeyCols::Packed {
                    a: "ps_partkey".into(),
                    shift: PS_SHIFT,
                    b: "ps_suppkey".into(),
                }),
                probe_key: Some(KeyCols::Packed {
                    a: "l_partkey".into(),
                    shift: PS_SHIFT,
                    b: "l_suppkey".into(),
                }),
                filter: PredExpr::True,
                link: None,
                payloads: vec![Payload::Col("ps_supplycost".into())],
            },
            // Supplier → nation.
            JoinStep {
                table: TableRef::Supplier,
                dense: false,
                build_key: Some(KeyCols::Col("s_suppkey".into())),
                probe_key: Some(KeyCols::Col("l_suppkey".into())),
                filter: PredExpr::True,
                link: None,
                payloads: vec![Payload::Col("s_nationkey".into())],
            },
            // Orders → order date (dense: orderkey is 1..=N).
            JoinStep {
                table: TableRef::Orders,
                dense: true,
                build_key: None,
                probe_key: Some(KeyCols::Col("l_orderkey".into())),
                filter: PredExpr::True,
                link: None,
                payloads: vec![Payload::Col("o_orderdate".into())],
            },
        ],
        cmps: vec![],
        key: kpack(kpay(2, 0), 16, kyear(kpay(3, 0))),
        slots: vec![vsub(vrevenue(), vmul(vpay(1, 0), vcol("l_quantity")))],
        groups_hint: GroupsHint::Const(256),
        finalize: FinalizeSpec {
            scalar: false,
            columns: vec![
                OutCol::KeyNation { shift: 16, bits: 0 },
                OutCol::KeyInt { shift: 0, bits: 16 },
                OutCol::Acc(0),
            ],
            having_gt: None,
            sort: vec![(0, SortDir::Asc), (1, SortDir::Desc)],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q9 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let part = &db.part;
    let mut green_parts: HashMap<i64, ()> = HashMap::new();
    for i in 0..part.len() {
        if part.col("p_name").str_at(i).contains(COLOR) {
            green_parts.insert(part.col("p_partkey").as_i64()[i], ());
        }
    }
    let ps = &db.partsupp;
    let mut cost: HashMap<(i64, i64), f64> = HashMap::new();
    for i in 0..ps.len() {
        cost.insert(
            (ps.col("ps_partkey").as_i64()[i], ps.col("ps_suppkey").as_i64()[i]),
            ps.col("ps_supplycost").as_f64()[i],
        );
    }
    let sup = &db.supplier;
    let mut nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..sup.len() {
        nat.insert(sup.col("s_suppkey").as_i64()[i], sup.col("s_nationkey").as_i32()[i] as i64);
    }
    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    let li = &db.lineitem;
    let mut groups: HashMap<(i64, i64), f64> = HashMap::new();
    for i in 0..li.len() {
        let pk = li.col("l_partkey").as_i64()[i];
        if !green_parts.contains_key(&pk) {
            continue;
        }
        let sk = li.col("l_suppkey").as_i64()[i];
        let Some(c) = cost.get(&(pk, sk)) else { continue };
        let Some(n) = nat.get(&sk) else { continue };
        let ok = li.col("l_orderkey").as_i64()[i];
        let (year, _, _) = days_to_date(odate[(ok - 1) as usize]);
        let profit = li.col("l_extendedprice").as_f64()[i]
            * (1.0 - li.col("l_discount").as_f64()[i])
            - c * li.col("l_quantity").as_f64()[i];
        *groups.entry((*n, year as i64)).or_insert(0.0) += profit;
    }
    let mut rows: Vec<Row> = groups
        .into_iter()
        .map(|((n, y), p)| {
            vec![Value::Str(NATIONS[n as usize].0.to_string()), Value::Int(y), Value::Float(p)]
        })
        .collect();
    rows.sort_by(|a, b| {
        let na = match &a[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        let nb = match &b[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        na.cmp(&nb).then(b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap())
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 37));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty(), "q9 returned nothing");
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized {} rows vs oracle {} rows",
            out.rows.len(),
            oracle.len()
        );
    }

    #[test]
    fn years_in_tpch_range() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 41));
        for r in run(&db).rows {
            match r[1] {
                Value::Int(y) => assert!((1992..=1998).contains(&y), "year {y}"),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn color_param_changes_the_part_set() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 41));
        let mut bag = PlanParams::new();
        bag.set("color", "azure");
        let out = engine::run_serial(&db, &logical(&bag).unwrap());
        // A different color selects a different (non-identical) result.
        let green = run(&db);
        let sum = |o: &QueryOutput| -> f64 { o.rows.iter().map(|r| r[2].as_f64()).sum() };
        assert!(
            (sum(&out) - sum(&green)).abs() > 1e-9 || out.rows.len() != green.rows.len(),
            "azure and green selected identical profit sets"
        );
    }

    #[test]
    fn composite_key_injective_at_scale() {
        // suppkey < 2^21 must hold for the packing.
        let db = TpchDb::generate(TpchConfig::new(0.002, 43));
        let max_sk = *db.partsupp.col("ps_suppkey").as_i64().iter().max().unwrap();
        assert!(max_sk < (1 << PS_SHIFT));
        assert_ne!(ps_key(1, 2), ps_key(2, 1));
    }
}

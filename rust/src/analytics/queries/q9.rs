//! TPC-H Q9 — product-type profit measure: profit by nation and year for
//! parts whose name contains a color word.
//!
//! The widest join tree we implement (part ⋈ partsupp ⋈ lineitem ⋈
//! supplier ⋈ orders) with a composite-key lookup into partsupp and a
//! substring filter on part names.

use crate::analytics::column::days_to_date;
use crate::analytics::engine::{
    self, BatchEval, Compiled, EvalBatch, HashJoinTable, PlanSpec, Predicate, Sel,
};
use crate::analytics::ops::{all_rows, ExecStats};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::{TpchDb, NATIONS};

const COLOR: &str = "green";

/// Composite (partkey, suppkey) → i64 key. Safe while suppkey < 2^21.
#[inline]
fn ps_key(partkey: i64, suppkey: i64) -> i64 {
    (partkey << 21) | suppkey
}

/// The one Q9 plan: part/partsupp/supplier hash tables built once at
/// compile time; the kernel runs the full probe chain per lineitem and
/// sums profit per (nation, year).
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q9", width: 1, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();

    // parts with COLOR in the name.
    let part = &db.part;
    let (dict, codes) = part.col("p_name").as_str_codes();
    stats.scan(part.len(), 4);
    let color_code: Vec<bool> = dict.iter().map(|s| s.contains(COLOR)).collect();
    let pkeys = part.col("p_partkey").as_i64();
    let part_sel: Vec<u32> = all_rows(part.len())
        .into_iter()
        .filter(|&i| color_code[codes[i as usize] as usize])
        .collect();
    let part_map = HashJoinTable::build_dim(pkeys, &part_sel, &mut stats);

    // partsupp composite index → supplycost.
    let ps = &db.partsupp;
    let ps_pk = ps.col("ps_partkey").as_i64();
    let ps_sk = ps.col("ps_suppkey").as_i64();
    let ps_cost = ps.col("ps_supplycost").as_f64();
    stats.scan(ps.len(), 24);
    let ps_keys: Vec<i64> = (0..ps.len()).map(|i| ps_key(ps_pk[i], ps_sk[i])).collect();
    let ps_map = HashJoinTable::build_dim(&ps_keys, &all_rows(ps.len()), &mut stats);

    // supplier → nation.
    let sup = &db.supplier;
    let skeys = sup.col("s_suppkey").as_i64();
    let snat = sup.col("s_nationkey").as_i32();
    stats.scan(sup.len(), 12);
    let sup_map = HashJoinTable::build_dim(skeys, &all_rows(sup.len()), &mut stats);

    // orders → year (dense array: orderkey is 1..=N).
    let odate = db.orders.col("o_orderdate").as_i32();
    stats.scan(db.orders.len(), 4);

    // lineitem probe chain.
    let li = &db.lineitem;
    let lok = li.col("l_orderkey").as_i64();
    let lpk = li.col("l_partkey").as_i64();
    let lsk = li.col("l_suppkey").as_i64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            if part_map.probe_first(lpk[i]).is_none() {
                return;
            }
            let Some(ps_row) = ps_map.probe_first(ps_key(lpk[i], lsk[i])) else { return };
            let Some(srow) = sup_map.probe_first(lsk[i]) else { return };
            let nation = snat[srow as usize] as i64;
            let (year, _, _) = days_to_date(odate[(lok[i] - 1) as usize]);
            let profit = price[i] * (1.0 - disc[i]) - ps_cost[ps_row as usize] * qty[i];
            out.keys.push((nation << 16) | year as i64);
            out.cols[0].push(profit);
        });
    });
    (Compiled { pred: Predicate::True, payload_bytes: 8 * 6, eval, groups_hint: 256 }, stats)
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let mut rows: Vec<Row> = (0..p.len())
        .map(|i| {
            let key = p.keys[i];
            vec![
                Value::Str(NATIONS[(key >> 16) as usize].0.to_string()),
                Value::Int(key & 0xffff),
                Value::Float(p.acc(i)[0]),
            ]
        })
        .collect();
    rows.sort_by(|a, b| {
        let na = match &a[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        let nb = match &b[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        na.cmp(&nb).then(b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap())
    });
    rows
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    use std::collections::HashMap;
    let part = &db.part;
    let mut green_parts: HashMap<i64, ()> = HashMap::new();
    for i in 0..part.len() {
        if part.col("p_name").str_at(i).contains(COLOR) {
            green_parts.insert(part.col("p_partkey").as_i64()[i], ());
        }
    }
    let ps = &db.partsupp;
    let mut cost: HashMap<(i64, i64), f64> = HashMap::new();
    for i in 0..ps.len() {
        cost.insert(
            (ps.col("ps_partkey").as_i64()[i], ps.col("ps_suppkey").as_i64()[i]),
            ps.col("ps_supplycost").as_f64()[i],
        );
    }
    let sup = &db.supplier;
    let mut nat: HashMap<i64, i64> = HashMap::new();
    for i in 0..sup.len() {
        nat.insert(sup.col("s_suppkey").as_i64()[i], sup.col("s_nationkey").as_i32()[i] as i64);
    }
    let orders = &db.orders;
    let odate = orders.col("o_orderdate").as_i32();
    let li = &db.lineitem;
    let mut groups: HashMap<(i64, i64), f64> = HashMap::new();
    for i in 0..li.len() {
        let pk = li.col("l_partkey").as_i64()[i];
        if !green_parts.contains_key(&pk) {
            continue;
        }
        let sk = li.col("l_suppkey").as_i64()[i];
        let Some(c) = cost.get(&(pk, sk)) else { continue };
        let Some(n) = nat.get(&sk) else { continue };
        let ok = li.col("l_orderkey").as_i64()[i];
        let (year, _, _) = days_to_date(odate[(ok - 1) as usize]);
        let profit = li.col("l_extendedprice").as_f64()[i]
            * (1.0 - li.col("l_discount").as_f64()[i])
            - c * li.col("l_quantity").as_f64()[i];
        *groups.entry((*n, year as i64)).or_insert(0.0) += profit;
    }
    let mut rows: Vec<Row> = groups
        .into_iter()
        .map(|((n, y), p)| {
            vec![Value::Str(NATIONS[n as usize].0.to_string()), Value::Int(y), Value::Float(p)]
        })
        .collect();
    rows.sort_by(|a, b| {
        let na = match &a[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        let nb = match &b[0] {
            Value::Str(s) => s.clone(),
            _ => unreachable!(),
        };
        na.cmp(&nb).then(b[1].as_f64().partial_cmp(&a[1].as_f64()).unwrap())
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 37));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(!out.rows.is_empty(), "q9 returned nothing");
        assert!(
            out.approx_eq_rows(&oracle),
            "vectorized {} rows vs oracle {} rows",
            out.rows.len(),
            oracle.len()
        );
    }

    #[test]
    fn years_in_tpch_range() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 41));
        for r in run(&db).rows {
            match r[1] {
                Value::Int(y) => assert!((1992..=1998).contains(&y), "year {y}"),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn composite_key_injective_at_scale() {
        // suppkey < 2^21 must hold for the packing.
        let db = TpchDb::generate(TpchConfig::new(0.002, 43));
        let max_sk = *db.partsupp.col("ps_suppkey").as_i64().iter().max().unwrap();
        assert!(max_sk < (1 << 21));
        assert_ne!(ps_key(1, 2), ps_key(2, 1));
    }
}

//! TPC-H Q19 — discounted revenue: three OR'd brand/container/quantity
//! predicate branches over lineitem ⋈ part.
//!
//! Exercises complex disjunctive predicates with part-side attribute
//! lookups (brand + container + size) fused into the probe loop.

use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

struct Branch {
    brand: &'static str,
    containers: &'static [&'static str],
    qty_lo: f64,
    qty_hi: f64,
    size_max: i32,
}

fn branches() -> [Branch; 3] {
    [
        Branch {
            brand: "Brand#12",
            containers: &["SM CASE", "SM BOX", "SM PACK", "SM PKG"],
            qty_lo: 1.0,
            qty_hi: 11.0,
            size_max: 5,
        },
        Branch {
            brand: "Brand#23",
            containers: &["MED BAG", "MED BOX"],
            qty_lo: 10.0,
            qty_hi: 20.0,
            size_max: 10,
        },
        Branch {
            brand: "Brand#34",
            containers: &["LG CASE", "LG BOX"],
            qty_lo: 20.0,
            qty_hi: 30.0,
            size_max: 15,
        },
    ]
}

const MODES: [&str; 2] = ["AIR", "REG AIR"];
const INSTRUCT: &str = "DELIVER IN PERSON";

/// The one Q19 plan: the per-part branch ids are precomputed once at
/// compile time (broadcast side); the mode/instruct dictionary tests run
/// as the predicate cascade and the kernel fuses the per-branch quantity
/// window into the revenue sum.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q19", width: 1, compile, finalize }
}

fn compile<'a>(db: &'a TpchDb) -> (Compiled<'a>, ExecStats) {
    let mut stats = ExecStats::default();
    let part = &db.part;
    let (brand_dict, brand_codes) = part.col("p_brand").as_str_codes();
    let (cont_dict, cont_codes) = part.col("p_container").as_str_codes();
    let size = part.col("p_size").as_i32();
    stats.scan(part.len(), 12);

    // Per-part branch id (0-2) or -1: precomputed once, probed per line.
    let brs = branches();
    let part_branch: Vec<i8> = (0..part.len())
        .map(|i| {
            let b = &brand_dict[brand_codes[i] as usize];
            let c = &cont_dict[cont_codes[i] as usize];
            for (bi, br) in brs.iter().enumerate() {
                if b == br.brand
                    && br.containers.contains(&c.as_str())
                    && size[i] >= 1
                    && size[i] <= br.size_max
                {
                    return bi as i8;
                }
            }
            -1
        })
        .collect();

    let li = &db.lineitem;
    let pred = Predicate::and(vec![
        Predicate::code_matches(li.col("l_shipmode"), |m| MODES.contains(&m)),
        Predicate::code_matches(li.col("l_shipinstruct"), |s| s == INSTRUCT),
    ]);
    let lpk = li.col("l_partkey").as_i64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let disc = li.col("l_discount").as_f64();
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            let bi = part_branch[(lpk[i] - 1) as usize];
            if bi < 0 {
                return;
            }
            let br = &brs[bi as usize];
            if qty[i] >= br.qty_lo && qty[i] <= br.qty_hi {
                out.keys.push(0);
                out.cols[0].push(price[i] * (1.0 - disc[i]));
            }
        });
    });
    (Compiled { pred, payload_bytes: 8 * 4, eval, groups_hint: 1 }, stats)
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let rev = if p.is_empty() { 0.0 } else { p.acc(0)[0] };
    vec![vec![Value::Float(rev)]]
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let part = &db.part;
    let li = &db.lineitem;
    let brs = branches();
    let mut revenue = 0.0;
    for i in 0..li.len() {
        if !MODES.contains(&li.col("l_shipmode").str_at(i)) {
            continue;
        }
        if li.col("l_shipinstruct").str_at(i) != INSTRUCT {
            continue;
        }
        let prow = (li.col("l_partkey").as_i64()[i] - 1) as usize;
        let brand = part.col("p_brand").str_at(prow);
        let cont = part.col("p_container").str_at(prow);
        let sz = part.col("p_size").as_i32()[prow];
        let q = li.col("l_quantity").as_f64()[i];
        for br in &brs {
            if brand == br.brand
                && br.containers.contains(&cont)
                && (1..=br.size_max).contains(&sz)
                && q >= br.qty_lo
                && q <= br.qty_hi
            {
                revenue += li.col("l_extendedprice").as_f64()[i]
                    * (1.0 - li.col("l_discount").as_f64()[i]);
                break;
            }
        }
    }
    vec![vec![Value::Float(revenue)]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 83));
        let out = run(&db);
        assert!(out.approx_eq_rows(&naive(&db)), "{:?}", out.rows);
    }

    #[test]
    fn revenue_nonnegative_and_selective() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 89));
        let out = run(&db);
        assert!(out.rows[0][0].as_f64() >= 0.0);
        // Very selective: the aggregate collapses to at most one group.
        assert!(out.stats.rows_out <= 1);
        assert!(out.stats.bytes_scanned > 0);
    }
}

//! TPC-H Q19 — discounted revenue: three OR'd brand/container/quantity
//! predicate branches over lineitem ⋈ part.
//!
//! Exercises disjunctive dimension predicates in the IR: the part step's
//! `CaseConst` payloads classify each part into a branch (no match →
//! excluded from the join), flowing that branch's quantity bounds to the
//! probe row, where two post-join compares apply the window.

use crate::analytics::engine::plan::{
    cmp, i32_range, kconst, pand, str_eq, str_in, vcol, vpay, vrevenue, CmpOp, FinalizeSpec,
    GroupsHint, JoinStep, KeyCols, LogicalPlan, OutCol, Payload, PredExpr, StrMatch, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

struct Branch {
    brand: &'static str,
    containers: &'static [&'static str],
    qty_lo: f64,
    qty_hi: f64,
    size_max: i32,
}

fn branches() -> [Branch; 3] {
    [
        Branch {
            brand: "Brand#12",
            containers: &["SM CASE", "SM BOX", "SM PACK", "SM PKG"],
            qty_lo: 1.0,
            qty_hi: 11.0,
            size_max: 5,
        },
        Branch {
            brand: "Brand#23",
            containers: &["MED BAG", "MED BOX"],
            qty_lo: 10.0,
            qty_hi: 20.0,
            size_max: 10,
        },
        Branch {
            brand: "Brand#34",
            containers: &["LG CASE", "LG BOX"],
            qty_lo: 20.0,
            qty_hi: 30.0,
            size_max: 15,
        },
    ]
}

const MODES: [&str; 2] = ["AIR", "REG AIR"];
const INSTRUCT: &str = "DELIVER IN PERSON";

/// A branch's part-side predicate: brand equality, container IN-list,
/// size window.
fn branch_pred(b: &Branch) -> PredExpr {
    let containers: Vec<String> = b.containers.iter().map(|c| c.to_string()).collect();
    pand(vec![
        str_eq("p_brand", b.brand),
        PredExpr::Str { col: "p_container".into(), m: StrMatch::OneOf(containers) },
        i32_range("p_size", 1, b.size_max + 1),
    ])
}

/// The one Q19 IR constructor: the mode/instruct dictionary tests run as
/// the scan cascade; the dense part step's `CaseConst` payloads carry
/// each matching branch's quantity bounds (non-matching parts never
/// join); two compares fuse the per-branch window into the revenue sum.
/// Parameter keys: `modes` (comma list), `instruct`.
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let modes = p.get_list("modes", &MODES)?;
    let instruct = p.get_str("instruct", INSTRUCT)?;
    let brs = branches();
    let lo_cases = brs.iter().map(|b| (branch_pred(b), b.qty_lo)).collect();
    let hi_cases = brs.iter().map(|b| (branch_pred(b), b.qty_hi)).collect();
    Ok(LogicalPlan {
        name: "q19".into(),
        scan: TableRef::Lineitem,
        pred: pand(vec![
            str_in("l_shipmode", &modes),
            str_eq("l_shipinstruct", &instruct),
        ]),
        joins: vec![JoinStep {
            table: TableRef::Part,
            dense: true,
            build_key: None,
            probe_key: Some(KeyCols::Col("l_partkey".into())),
            filter: PredExpr::True,
            link: None,
            payloads: vec![
                Payload::CaseConst { cases: lo_cases },
                Payload::CaseConst { cases: hi_cases },
            ],
        }],
        cmps: vec![
            cmp(vcol("l_quantity"), CmpOp::Ge, vpay(0, 0)),
            cmp(vcol("l_quantity"), CmpOp::Le, vpay(0, 1)),
        ],
        key: kconst(0),
        slots: vec![vrevenue()],
        groups_hint: GroupsHint::Const(1),
        finalize: FinalizeSpec {
            scalar: true,
            columns: vec![OutCol::Acc(0)],
            having_gt: None,
            sort: vec![],
            limit: 0,
        },
    })
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q19 plan"))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let part = &db.part;
    let li = &db.lineitem;
    let brs = branches();
    let mut revenue = 0.0;
    for i in 0..li.len() {
        if !MODES.contains(&li.col("l_shipmode").str_at(i)) {
            continue;
        }
        if li.col("l_shipinstruct").str_at(i) != INSTRUCT {
            continue;
        }
        let prow = (li.col("l_partkey").as_i64()[i] - 1) as usize;
        let brand = part.col("p_brand").str_at(prow);
        let cont = part.col("p_container").str_at(prow);
        let sz = part.col("p_size").as_i32()[prow];
        let q = li.col("l_quantity").as_f64()[i];
        for br in &brs {
            if brand == br.brand
                && br.containers.contains(&cont)
                && (1..=br.size_max).contains(&sz)
                && q >= br.qty_lo
                && q <= br.qty_hi
            {
                revenue += li.col("l_extendedprice").as_f64()[i]
                    * (1.0 - li.col("l_discount").as_f64()[i]);
                break;
            }
        }
    }
    vec![vec![Value::Float(revenue)]]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 83));
        let out = run(&db);
        assert!(out.approx_eq_rows(&naive(&db)), "{:?}", out.rows);
    }

    #[test]
    fn revenue_nonnegative_and_selective() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 89));
        let out = run(&db);
        assert!(out.rows[0][0].as_f64() >= 0.0);
        // Very selective: the aggregate collapses to at most one group.
        assert!(out.stats.rows_out <= 1);
        assert!(out.stats.bytes_scanned > 0);
    }

    #[test]
    fn modes_param_can_only_grow_revenue() {
        let db = TpchDb::generate(TpchConfig::new(0.01, 89));
        let base = run(&db).rows[0][0].as_f64();
        let mut bag = PlanParams::new();
        bag.set("modes", "AIR,REG AIR,TRUCK,RAIL,SHIP,MAIL,FOB");
        let all = engine::run_serial(&db, &logical(&bag).unwrap()).rows[0][0].as_f64();
        assert!(all >= base, "a superset of modes must not lose revenue");
    }
}

//! TPC-H Q6 — forecasting revenue change.
//!
//! A pure scan: date window + discount band + quantity cap, then a single
//! sum. The paper singles Q6 out as the *compute-bound* exception in
//! Figure 3 ("performs a compute-bound scan of data in memory") — its
//! working set is a handful of narrow columns and it does almost no
//! pointer chasing, so on x86 the slowdown comes from SMT sharing rather
//! than DRAM bandwidth.
//!
//! This is also the query the PJRT offload path accelerates: see
//! `python/compile/kernels/q6_scan.py` and `runtime::q6`.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::{self, BatchEval, Compiled, EvalBatch, PlanSpec, Predicate, Sel};
use crate::analytics::ops::ExecStats;
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

pub struct Q6Params {
    pub date_lo: i32,
    pub date_hi: i32,
    pub disc_lo: f64,
    pub disc_hi: f64,
    pub qty_lt: f64,
}

impl Default for Q6Params {
    fn default() -> Self {
        Self {
            date_lo: date_to_days(1994, 1, 1),
            date_hi: date_to_days(1995, 1, 1),
            // discount between 0.06 - 0.01 and 0.06 + 0.01 (inclusive);
            // discounts are multiples of 0.01 so half-open [0.045, 0.075).
            disc_lo: 0.045,
            disc_hi: 0.075,
            qty_lt: 24.0,
        }
    }
}

/// Aggregate slots per group — shared by `plan_spec` and `run_params`
/// so the two entry points cannot drift.
const WIDTH: usize = 1;

/// The one Q6 plan: a three-conjunct predicate cascade and a single
/// revenue accumulator; finalize reads the one merged slot.
pub(crate) fn plan_spec() -> PlanSpec {
    PlanSpec { name: "q6", width: WIDTH, compile, finalize }
}

fn compile(db: &TpchDb) -> (Compiled<'_>, ExecStats) {
    compile_params(db, &Q6Params::default())
}

fn compile_params<'a>(db: &'a TpchDb, p: &Q6Params) -> (Compiled<'a>, ExecStats) {
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let pred = Predicate::and(vec![
        Predicate::i32_range(ship, p.date_lo, p.date_hi),
        Predicate::f64_range(disc, p.disc_lo, p.disc_hi),
        Predicate::f64_lt(qty, p.qty_lt),
    ]);
    let eval: BatchEval<'a> = Box::new(move |rows: Sel<'_>, out: &mut EvalBatch| {
        rows.for_each(|i| {
            out.keys.push(0);
            out.cols[0].push(price[i] * disc[i]);
        });
    });
    (Compiled { pred, payload_bytes: 8, eval, groups_hint: 1 }, ExecStats::default())
}

fn finalize(_db: &TpchDb, p: &engine::Partial) -> Vec<Row> {
    let rev = if p.is_empty() { 0.0 } else { p.acc(0)[0] };
    vec![vec![Value::Float(rev)]]
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &plan_spec())
}

/// Run with explicit parameters (used by the PJRT-offload comparisons
/// and the parameter-sweep tests) — same engine kernel, custom window.
pub fn run_params(db: &TpchDb, p: &Q6Params) -> QueryOutput {
    let (c, prep) = compile_params(db, p);
    engine::run_serial_compiled(db, WIDTH, &c, prep, finalize)
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let p = Q6Params::default();
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let mut revenue = 0.0;
    for i in 0..li.len() {
        if ship[i] >= p.date_lo
            && ship[i] < p.date_hi
            && disc[i] >= p.disc_lo
            && disc[i] < p.disc_hi
            && qty[i] < p.qty_lt
        {
            revenue += price[i] * disc[i];
        }
    }
    vec![vec![Value::Float(revenue)]]
}

/// The flat inputs the PJRT Q6 kernel consumes (see `runtime::q6`):
/// (shipdate as f32-able i32, discount, quantity, extendedprice).
pub fn kernel_inputs(db: &TpchDb) -> (&[i32], &[f64], &[f64], &[f64]) {
    let li = &db.lineitem;
    (
        li.col("l_shipdate").as_i32(),
        li.col("l_discount").as_f64(),
        li.col("l_quantity").as_f64(),
        li.col("l_extendedprice").as_f64(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 13));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{:?} vs {oracle:?}", out.rows);
        // Selectivity sanity: some rows matched, far from the whole scan.
        assert!(out.stats.rows_out > 0);
        assert!((out.stats.rows_out as usize) < db.lineitem.len() / 10);
    }

    #[test]
    fn revenue_positive_and_scales_with_sf() {
        let small = run(&TpchDb::generate(TpchConfig::new(0.001, 9)));
        let large = run(&TpchDb::generate(TpchConfig::new(0.004, 9)));
        let (rs, rl) = (small.rows[0][0].as_f64(), large.rows[0][0].as_f64());
        assert!(rs > 0.0);
        // 4x data → roughly 4x revenue (generous band).
        let ratio = rl / rs;
        assert!(ratio > 2.5 && ratio < 6.0, "ratio={ratio}");
    }

    #[test]
    fn empty_window_gives_zero() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 9));
        let p = Q6Params { date_lo: 0, date_hi: 1, ..Default::default() };
        let out = run_params(&db, &p);
        assert_eq!(out.rows[0][0].as_f64(), 0.0);
    }

    #[test]
    fn low_intensity_vs_q1() {
        // Q6 touches fewer bytes than Q1 (the "compute-bound" shape).
        let db = TpchDb::generate(TpchConfig::new(0.002, 9));
        let q1 = crate::analytics::queries::q1::run(&db);
        let q6 = run(&db);
        assert!(q6.stats.bytes_scanned < q1.stats.bytes_scanned);
    }
}

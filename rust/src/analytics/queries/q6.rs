//! TPC-H Q6 — forecasting revenue change.
//!
//! A pure scan: date window + discount band + quantity cap, then a single
//! sum. The paper singles Q6 out as the *compute-bound* exception in
//! Figure 3 ("performs a compute-bound scan of data in memory") — its
//! working set is a handful of narrow columns and it does almost no
//! pointer chasing, so on x86 the slowdown comes from SMT sharing rather
//! than DRAM bandwidth.
//!
//! This is also the query the PJRT offload path accelerates: see
//! `python/compile/kernels/q6_scan.py` and `runtime::q6`.

use crate::analytics::column::date_to_days;
use crate::analytics::morsel::{MorselPlan, Partial, PartialFn};
use crate::analytics::ops::{all_rows, filter_f64_lt, filter_f64_range, filter_i32_range, sum_over, ExecStats};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;

pub struct Q6Params {
    pub date_lo: i32,
    pub date_hi: i32,
    pub disc_lo: f64,
    pub disc_hi: f64,
    pub qty_lt: f64,
}

impl Default for Q6Params {
    fn default() -> Self {
        Self {
            date_lo: date_to_days(1994, 1, 1),
            date_hi: date_to_days(1995, 1, 1),
            // discount between 0.06 - 0.01 and 0.06 + 0.01 (inclusive);
            // discounts are multiples of 0.01 so half-open [0.045, 0.075).
            disc_lo: 0.045,
            disc_hi: 0.075,
            qty_lt: 24.0,
        }
    }
}

pub fn run(db: &TpchDb) -> QueryOutput {
    run_params(db, &Q6Params::default())
}

pub fn run_params(db: &TpchDb, p: &Q6Params) -> QueryOutput {
    let li = &db.lineitem;
    let n = li.len();
    let mut stats = ExecStats::default();

    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();

    stats.scan(n, 4); // shipdate full scan
    let s1 = filter_i32_range(&all_rows(n), ship, p.date_lo, p.date_hi);
    stats.scan(s1.len(), 8);
    let s2 = filter_f64_range(&s1, disc, p.disc_lo, p.disc_hi);
    stats.scan(s2.len(), 8);
    let s3 = filter_f64_lt(&s2, qty, p.qty_lt);
    stats.scan(s3.len(), 8);
    let revenue = sum_over(&s3, |i| price[i as usize] * disc[i as usize]);
    stats.rows_out = s3.len() as u64;

    QueryOutput { rows: vec![vec![Value::Float(revenue)]], stats }
}

/// Morsel plan: the pure parallel scan — each morsel fuses the three
/// filters and the revenue sum; finalize reads the single accumulator.
pub(crate) fn morsel_plan() -> MorselPlan {
    MorselPlan { width: 1, prepare: morsel_prepare, finalize: morsel_finalize }
}

fn morsel_prepare<'a>(db: &'a TpchDb) -> (PartialFn<'a>, ExecStats) {
    let p = Q6Params::default();
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let kernel: PartialFn<'a> = Box::new(move |lo, hi| {
        let mut st = ExecStats::default();
        st.scan(hi - lo, 4 + 8 * 3);
        let mut rev = 0.0;
        let mut matched = 0u64;
        for i in lo..hi {
            if ship[i] >= p.date_lo
                && ship[i] < p.date_hi
                && disc[i] >= p.disc_lo
                && disc[i] < p.disc_hi
                && qty[i] < p.qty_lt
            {
                rev += price[i] * disc[i];
                matched += 1;
            }
        }
        st.rows_out = matched;
        Partial::single(0, &[rev], matched, st)
    });
    (kernel, ExecStats::default())
}

fn morsel_finalize(_db: &TpchDb, p: &Partial) -> Vec<Row> {
    let rev = if p.is_empty() { 0.0 } else { p.acc(0)[0] };
    vec![vec![Value::Float(rev)]]
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let p = Q6Params::default();
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let mut revenue = 0.0;
    for i in 0..li.len() {
        if ship[i] >= p.date_lo
            && ship[i] < p.date_hi
            && disc[i] >= p.disc_lo
            && disc[i] < p.disc_hi
            && qty[i] < p.qty_lt
        {
            revenue += price[i] * disc[i];
        }
    }
    vec![vec![Value::Float(revenue)]]
}

/// The flat inputs the PJRT Q6 kernel consumes (see `runtime::q6`):
/// (shipdate as f32-able i32, discount, quantity, extendedprice).
pub fn kernel_inputs(db: &TpchDb) -> (&[i32], &[f64], &[f64], &[f64]) {
    let li = &db.lineitem;
    (
        li.col("l_shipdate").as_i32(),
        li.col("l_discount").as_f64(),
        li.col("l_quantity").as_f64(),
        li.col("l_extendedprice").as_f64(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 13));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{:?} vs {oracle:?}", out.rows);
        // Selectivity sanity: a strict subset matched.
        assert!(out.stats.rows_out > 0);
        assert!((out.stats.rows_out as usize) < db.lineitem.len() / 10);
    }

    #[test]
    fn revenue_positive_and_scales_with_sf() {
        let small = run(&TpchDb::generate(TpchConfig::new(0.001, 9)));
        let large = run(&TpchDb::generate(TpchConfig::new(0.004, 9)));
        let (rs, rl) = (small.rows[0][0].as_f64(), large.rows[0][0].as_f64());
        assert!(rs > 0.0);
        // 4x data → roughly 4x revenue (generous band).
        let ratio = rl / rs;
        assert!(ratio > 2.5 && ratio < 6.0, "ratio={ratio}");
    }

    #[test]
    fn empty_window_gives_zero() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 9));
        let p = Q6Params { date_lo: 0, date_hi: 1, ..Default::default() };
        let out = run_params(&db, &p);
        assert_eq!(out.rows[0][0].as_f64(), 0.0);
    }

    #[test]
    fn low_intensity_vs_q1() {
        // Q6 touches fewer bytes than Q1 (the "compute-bound" shape).
        let db = TpchDb::generate(TpchConfig::new(0.002, 9));
        let q1 = crate::analytics::queries::q1::run(&db);
        let q6 = run(&db);
        assert!(q6.stats.bytes_scanned < q1.stats.bytes_scanned);
    }
}

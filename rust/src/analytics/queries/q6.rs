//! TPC-H Q6 — forecasting revenue change.
//!
//! A pure scan: date window + discount band + quantity cap, then a single
//! sum. The paper singles Q6 out as the *compute-bound* exception in
//! Figure 3 ("performs a compute-bound scan of data in memory") — its
//! working set is a handful of narrow columns and it does almost no
//! pointer chasing, so on x86 the slowdown comes from SMT sharing rather
//! than DRAM bandwidth.
//!
//! This is also the query the PJRT offload path accelerates: see
//! `python/compile/kernels/q6_scan.py` and `runtime::q6`.

use crate::analytics::column::date_to_days;
use crate::analytics::engine::plan::{
    f64_lt, f64_range, i32_range, kconst, pand, vcol, vmul, FinalizeSpec, GroupsHint,
    LogicalPlan, OutCol, TableRef,
};
use crate::analytics::engine::{self, PlanParams};
use crate::analytics::queries::{QueryOutput, Row, Value};
use crate::analytics::tpch::TpchDb;
use crate::error::Result;

pub struct Q6Params {
    pub date_lo: i32,
    pub date_hi: i32,
    pub disc_lo: f64,
    pub disc_hi: f64,
    pub qty_lt: f64,
}

impl Default for Q6Params {
    fn default() -> Self {
        Self {
            date_lo: date_to_days(1994, 1, 1),
            date_hi: date_to_days(1995, 1, 1),
            // discount between 0.06 - 0.01 and 0.06 + 0.01 (inclusive);
            // discounts are multiples of 0.01 so half-open [0.045, 0.075).
            disc_lo: 0.045,
            disc_hi: 0.075,
            qty_lt: 24.0,
        }
    }
}

/// The one Q6 IR constructor. Parameter keys: `date-lo`/`date-hi`
/// (shipdate window), `disc-lo`/`disc-hi` (discount band), `qty-lt`
/// (quantity cap).
pub fn logical(p: &PlanParams) -> Result<LogicalPlan> {
    let d = Q6Params::default();
    Ok(logical_params(&Q6Params {
        date_lo: p.get_date("date-lo", d.date_lo)?,
        date_hi: p.get_date("date-hi", d.date_hi)?,
        disc_lo: p.get_f64("disc-lo", d.disc_lo)?,
        disc_hi: p.get_f64("disc-hi", d.disc_hi)?,
        qty_lt: p.get_f64("qty-lt", d.qty_lt)?,
    }))
}

/// The Q6 plan for explicit parameters: a three-conjunct predicate
/// cascade and a single `price · discount` accumulator; finalize reads
/// the one merged slot (scalar — an empty window reports 0 revenue).
pub fn logical_params(p: &Q6Params) -> LogicalPlan {
    LogicalPlan {
        name: "q6".into(),
        scan: TableRef::Lineitem,
        pred: pand(vec![
            i32_range("l_shipdate", p.date_lo, p.date_hi),
            f64_range("l_discount", p.disc_lo, p.disc_hi),
            f64_lt("l_quantity", p.qty_lt),
        ]),
        joins: vec![],
        cmps: vec![],
        key: kconst(0),
        slots: vec![vmul(vcol("l_extendedprice"), vcol("l_discount"))],
        groups_hint: GroupsHint::Const(1),
        finalize: FinalizeSpec {
            scalar: true,
            columns: vec![OutCol::Acc(0)],
            having_gt: None,
            sort: vec![],
            limit: 0,
        },
    }
}

/// Single-threaded reference execution (engine-driven).
pub fn run(db: &TpchDb) -> QueryOutput {
    engine::run_serial(db, &logical(&PlanParams::default()).expect("default q6 plan"))
}

/// Run with explicit parameters (used by the PJRT-offload comparisons
/// and the parameter-sweep tests) — same engine kernel, custom window.
pub fn run_params(db: &TpchDb, p: &Q6Params) -> QueryOutput {
    engine::run_serial(db, &logical_params(p))
}

/// Row-at-a-time oracle.
pub fn naive(db: &TpchDb) -> Vec<Row> {
    let p = Q6Params::default();
    let li = &db.lineitem;
    let ship = li.col("l_shipdate").as_i32();
    let disc = li.col("l_discount").as_f64();
    let qty = li.col("l_quantity").as_f64();
    let price = li.col("l_extendedprice").as_f64();
    let mut revenue = 0.0;
    for i in 0..li.len() {
        if ship[i] >= p.date_lo
            && ship[i] < p.date_hi
            && disc[i] >= p.disc_lo
            && disc[i] < p.disc_hi
            && qty[i] < p.qty_lt
        {
            revenue += price[i] * disc[i];
        }
    }
    vec![vec![Value::Float(revenue)]]
}

/// The flat inputs the PJRT Q6 kernel consumes (see `runtime::q6`):
/// (shipdate as f32-able i32, discount, quantity, extendedprice).
pub fn kernel_inputs(db: &TpchDb) -> (&[i32], &[f64], &[f64], &[f64]) {
    let li = &db.lineitem;
    (
        li.col("l_shipdate").as_i32(),
        li.col("l_discount").as_f64(),
        li.col("l_quantity").as_f64(),
        li.col("l_extendedprice").as_f64(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analytics::tpch::TpchConfig;

    #[test]
    fn matches_oracle() {
        let db = TpchDb::generate(TpchConfig::new(0.002, 13));
        let out = run(&db);
        let oracle = naive(&db);
        assert!(out.approx_eq_rows(&oracle), "{:?} vs {oracle:?}", out.rows);
        // Selectivity sanity: some rows matched, far from the whole scan.
        assert!(out.stats.rows_out > 0);
        assert!((out.stats.rows_out as usize) < db.lineitem.len() / 10);
    }

    #[test]
    fn revenue_positive_and_scales_with_sf() {
        let small = run(&TpchDb::generate(TpchConfig::new(0.001, 9)));
        let large = run(&TpchDb::generate(TpchConfig::new(0.004, 9)));
        let (rs, rl) = (small.rows[0][0].as_f64(), large.rows[0][0].as_f64());
        assert!(rs > 0.0);
        // 4x data → roughly 4x revenue (generous band).
        let ratio = rl / rs;
        assert!(ratio > 2.5 && ratio < 6.0, "ratio={ratio}");
    }

    #[test]
    fn empty_window_gives_zero() {
        let db = TpchDb::generate(TpchConfig::new(0.001, 9));
        let p = Q6Params { date_lo: 0, date_hi: 1, ..Default::default() };
        let out = run_params(&db, &p);
        assert_eq!(out.rows[0][0].as_f64(), 0.0);
    }

    #[test]
    fn params_flow_through_the_ir() {
        // `--param` overrides must produce the same plan as the typed
        // Q6Params form — the CLI path and the library path agree.
        let db = TpchDb::generate(TpchConfig::new(0.002, 13));
        let mut bag = PlanParams::new();
        bag.set("date-lo", "1995-01-01");
        bag.set("date-hi", "1996-01-01");
        bag.set("qty-lt", "30");
        let from_bag = logical(&bag).unwrap();
        let typed = logical_params(&Q6Params {
            date_lo: date_to_days(1995, 1, 1),
            date_hi: date_to_days(1996, 1, 1),
            qty_lt: 30.0,
            ..Q6Params::default()
        });
        assert_eq!(from_bag, typed);
        let out = engine::run_serial(&db, &from_bag);
        assert!(out.rows[0][0].as_f64() > 0.0);
    }

    #[test]
    fn low_intensity_vs_q1() {
        // Q6 touches fewer bytes than Q1 (the "compute-bound" shape).
        let db = TpchDb::generate(TpchConfig::new(0.002, 9));
        let q1 = crate::analytics::queries::q1::run(&db);
        let q6 = run(&db);
        assert!(q6.stats.bytes_scanned < q1.stats.bytes_scanned);
    }
}

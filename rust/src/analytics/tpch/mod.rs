//! TPC-H substrate: schema constants and the deterministic data generator.

pub mod gen;

pub use gen::{
    for_each_lineitem_chunk, lineitem_rows, lineitem_shard, LineitemChunk, TpchConfig, TpchDb,
};

/// Scale-factor-1 base cardinalities (TPC-H spec §4.2.5).
pub const SF1_ORDERS: usize = 1_500_000;
pub const SF1_CUSTOMER: usize = 150_000;
pub const SF1_PART: usize = 200_000;
pub const SF1_SUPPLIER: usize = 10_000;
pub const SUPPLIERS_PER_PART: usize = 4;

/// The 25 nations and their region assignment (TPC-H spec Appendix A).
pub const NATIONS: [(&str, u32); 25] = [
    ("ALGERIA", 0),
    ("ARGENTINA", 1),
    ("BRAZIL", 1),
    ("CANADA", 1),
    ("EGYPT", 4),
    ("ETHIOPIA", 0),
    ("FRANCE", 3),
    ("GERMANY", 3),
    ("INDIA", 2),
    ("INDONESIA", 2),
    ("IRAN", 4),
    ("IRAQ", 4),
    ("JAPAN", 2),
    ("JORDAN", 4),
    ("KENYA", 0),
    ("MOROCCO", 0),
    ("MOZAMBIQUE", 0),
    ("PERU", 1),
    ("CHINA", 2),
    ("ROMANIA", 3),
    ("SAUDI ARABIA", 4),
    ("VIETNAM", 2),
    ("RUSSIA", 3),
    ("UNITED KINGDOM", 3),
    ("UNITED STATES", 1),
];

pub const REGIONS: [&str; 5] = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"];

pub const SEGMENTS: [&str; 5] = ["AUTOMOBILE", "BUILDING", "FURNITURE", "MACHINERY", "HOUSEHOLD"];

pub const PRIORITIES: [&str; 5] = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"];

pub const SHIP_MODES: [&str; 7] = ["REG AIR", "AIR", "RAIL", "SHIP", "TRUCK", "MAIL", "FOB"];

pub const SHIP_INSTRUCTS: [&str; 4] =
    ["DELIVER IN PERSON", "COLLECT COD", "NONE", "TAKE BACK RETURN"];

/// Part-name color vocabulary (Q9 filters on a color substring).
pub const COLORS: [&str; 20] = [
    "almond", "antique", "aquamarine", "azure", "beige", "bisque", "black", "blanched", "blue",
    "blush", "brown", "burlywood", "burnished", "chartreuse", "chiffon", "chocolate", "coral",
    "cornflower", "green", "cream",
];

pub const CONTAINERS: [&str; 8] =
    ["SM CASE", "SM BOX", "SM PACK", "SM PKG", "MED BAG", "MED BOX", "LG CASE", "LG BOX"];

pub const TYPE_SYLL1: [&str; 6] = ["STANDARD", "SMALL", "MEDIUM", "LARGE", "ECONOMY", "PROMO"];
pub const TYPE_SYLL2: [&str; 5] = ["ANODIZED", "BURNISHED", "PLATED", "POLISHED", "BRUSHED"];
pub const TYPE_SYLL3: [&str; 5] = ["TIN", "NICKEL", "BRASS", "STEEL", "COPPER"];

//! Deterministic TPC-H data generator (`dbgen` replacement), streaming
//! and embarrassingly parallel.
//!
//! Every value is a pure function of `(seed, table, row)`: each table
//! has a PRNG *stream seed*, and each row draws from its own generator,
//! `Pcg64::seed_from_u64(stream ^ mix64(row))`. Any slice of any table
//! can therefore be produced independently, in any order, on any
//! thread, with no central materialization. Three consumers share one
//! chunk-producing core ([`for_each_lineitem_chunk`]):
//!
//! * [`TpchDb::generate`] — the full database, generated in parallel
//!   (each thread fills a chunk-aligned row range) with per-chunk
//!   min-max zone maps built as chunks are appended;
//! * [`lineitem_shard`] — a worker's partition `[lo, hi)`, bitwise
//!   identical to the same rows of the full table *by construction*
//!   (the distributed coordinator regenerates partitions in place
//!   instead of shipping table bytes);
//! * streaming consumers (benches, the SF-1 bounded-memory smoke),
//!   which observe one buffer of at most `chunk_rows` rows at a time
//!   and never hold a full column, so SF 10+ fits in constant memory.
//!
//! Order dates ramp monotonically over 1992–1998 (with bounded jitter)
//! and line quantities drift upward along that ramp. Both are mild,
//! realistic correlations — ledgers are append-mostly in time — and
//! they are what give the zone maps pruning power: a chunk's
//! `l_shipdate`/`l_quantity` min-max stays narrow instead of spanning
//! the whole domain.

use super::*;
use crate::analytics::chunkstore::{zones_f64, zones_i32, ColZones, Zone, ZoneMap, CHUNK_ROWS};
use crate::analytics::column::{date_to_days, Column, StrColumnBuilder, Table};
use crate::prng::Pcg64;

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct TpchConfig {
    /// Scale factor: 1.0 ≈ 1 GB of raw data (6M lineitems).
    pub sf: f64,
    pub seed: u64,
}

impl TpchConfig {
    pub fn new(sf: f64, seed: u64) -> Self {
        assert!(sf > 0.0);
        Self { sf, seed }
    }

    pub fn orders(&self) -> usize {
        ((SF1_ORDERS as f64 * self.sf) as usize).max(10)
    }
    pub fn customers(&self) -> usize {
        ((SF1_CUSTOMER as f64 * self.sf) as usize).max(5)
    }
    pub fn parts(&self) -> usize {
        ((SF1_PART as f64 * self.sf) as usize).max(5)
    }
    pub fn suppliers(&self) -> usize {
        ((SF1_SUPPLIER as f64 * self.sf) as usize).max(3)
    }
}

/// The generated database.
pub struct TpchDb {
    pub config: TpchConfig,
    pub lineitem: Table,
    pub orders: Table,
    pub customer: Table,
    pub part: Table,
    pub supplier: Table,
    pub partsupp: Table,
    pub nation: Table,
    pub region: Table,
}

/// TPC-H date constants (days since unix epoch).
pub struct Dates;
impl Dates {
    pub fn start() -> i32 {
        date_to_days(1992, 1, 1)
    }
    /// Last order date: 1998-08-02.
    pub fn end_orders() -> i32 {
        date_to_days(1998, 8, 2)
    }
    /// The returnflag pivot: 1995-06-17.
    pub fn current() -> i32 {
        date_to_days(1995, 6, 17)
    }
}

impl TpchDb {
    /// Generate the full database (lineitem and orders in parallel,
    /// all tables carrying zone maps).
    pub fn generate(config: TpchConfig) -> Self {
        let streams = Streams::new(config.seed);
        let dims = Dims::new(&config);
        let mut part = gen_part(&config, &streams);
        part.set_zones(ZoneMap::build_from(&part, CHUNK_ROWS));
        let mut supplier = gen_supplier(&config, &streams);
        supplier.set_zones(ZoneMap::build_from(&supplier, CHUNK_ROWS));
        let mut partsupp = gen_partsupp(&config, &streams);
        partsupp.set_zones(ZoneMap::build_from(&partsupp, CHUNK_ROWS));
        let mut customer = gen_customer(&config, &streams);
        customer.set_zones(ZoneMap::build_from(&customer, CHUNK_ROWS));
        let total = count_lineitem_rows(&streams, dims.n_orders);
        let lineitem = gen_lineitem_parallel(&config, total);
        let mut orders = gen_orders_parallel(&config, &streams, &dims);
        orders.set_zones(ZoneMap::build_from(&orders, CHUNK_ROWS));
        let (nation, region) = gen_nation_region();
        Self { config, lineitem, orders, customer, part, supplier, partsupp, nation, region }
    }

    /// Total raw bytes across tables.
    pub fn bytes(&self) -> u64 {
        self.lineitem.bytes()
            + self.orders.bytes()
            + self.customer.bytes()
            + self.part.bytes()
            + self.supplier.bytes()
            + self.partsupp.bytes()
            + self.nation.bytes()
            + self.region.bytes()
    }
}

// ------------------------------------------------------------- seeding

/// SplitMix64 finalizer: a cheap stateless hash that turns a row index
/// into a well-mixed 64-bit value. Used both to give every row its own
/// PRNG seed and to make per-order draws (line count, order date)
/// O(1) to recompute during prefix scans.
#[inline]
fn mix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Per-table stream seeds, all derived from the config seed.
struct Streams {
    part: u64,
    supplier: u64,
    partsupp: u64,
    customer: u64,
    order: u64,
    line: u64,
    lines: u64,
    odate: u64,
}

impl Streams {
    fn new(seed: u64) -> Self {
        let root = Pcg64::seed_from_u64(seed);
        let s = |tag: &str| {
            let mut r = root.derive(tag);
            r.next_u64()
        };
        Self {
            part: s("part"),
            supplier: s("supplier"),
            partsupp: s("partsupp"),
            customer: s("customer"),
            order: s("orders"),
            line: s("lineitem"),
            lines: s("lines"),
            odate: s("odate"),
        }
    }
}

/// The row's private generator: every draw sequence below starts here.
#[inline]
fn row_rng(stream: u64, row: usize) -> Pcg64 {
    Pcg64::seed_from_u64(stream ^ mix64(row as u64))
}

/// Cardinalities and date constants captured once per generation.
#[derive(Clone, Copy)]
struct Dims {
    n_cust: i64,
    n_parts: i64,
    n_sups: i64,
    n_orders: usize,
    start: i32,
    date_span: i32,
}

impl Dims {
    fn new(cfg: &TpchConfig) -> Self {
        Self {
            n_cust: cfg.customers() as i64,
            n_parts: cfg.parts() as i64,
            n_sups: cfg.suppliers() as i64,
            n_orders: cfg.orders(),
            start: Dates::start(),
            date_span: Dates::end_orders() - Dates::start(),
        }
    }

    /// Position of order `oi` along the generation ramp, in `[0, 1]`.
    #[inline]
    fn order_frac(&self, oi: usize) -> f64 {
        oi as f64 / (self.n_orders - 1).max(1) as f64
    }
}

/// Lines in order `oi` (1–7, mean 4). O(1) — a prefix scan over these
/// is how any consumer maps a global lineitem row to its order.
#[inline]
fn lines_in_order(streams: &Streams, oi: usize) -> usize {
    1 + (mix64(streams.lines ^ oi as u64) % 7) as usize
}

/// Order date for `oi`: a monotone ramp over 1992–1998 plus up to 30
/// days of jitter. Stays within `[start, end_orders]`.
#[inline]
fn order_date(streams: &Streams, d: &Dims, oi: usize) -> i32 {
    let ramp = (d.date_span - 31) as i64 * oi as i64 / (d.n_orders - 1).max(1) as i64;
    d.start + ramp as i32 + (mix64(streams.odate ^ oi as u64) % 31) as i32
}

/// Closed-form p_retailprice (the spec's formula shape). Being closed
/// form lets lineitem pricing run without the part table in scope.
#[inline]
fn retail_price(part_index: usize) -> f64 {
    900.0 + (part_index as f64 % 1000.0) / 10.0 + (part_index % 100) as f64
}

// ------------------------------------------------------- lineitem core

/// All generated values of one lineitem row.
struct LineVals {
    partkey: i64,
    suppkey: i64,
    quantity: f64,
    price: f64,
    discount: f64,
    tax: f64,
    ship: i32,
    commit: i32,
    receipt: i32,
    rflag: u8,
    lstatus: u8,
    mode: u32,
    instr: u32,
}

/// Lineitem row `r` (line of order `oi`, which has date `odate`) — a
/// pure function of the seed and the row coordinates. Shared by the
/// chunk producer and the orders pass (which re-derives its lines to
/// compute o_totalprice / o_orderstatus), so worker shards are bitwise
/// identical to the full table by construction.
fn line_vals(streams: &Streams, d: &Dims, r: usize, oi: usize, odate: i32) -> LineVals {
    let mut rng = row_rng(streams.line, r);
    let partkey = rng.gen_range_i64(1, d.n_parts);
    let suppkey = rng.gen_range_i64(1, d.n_sups);
    let qjit = rng.gen_range_i64(-8, 8);
    let discount = rng.gen_range_i64(0, 10) as f64 / 100.0;
    let tax = rng.gen_range_i64(0, 8) as f64 / 100.0;
    let ship = odate + rng.gen_range_i64(1, 121) as i32;
    let commit = odate + rng.gen_range_i64(30, 90) as i32;
    let receipt = ship + rng.gen_range_i64(1, 30) as i32;
    let mode = rng.gen_range_u64(SHIP_MODES.len() as u64) as u32;
    let instr = rng.gen_range_u64(SHIP_INSTRUCTS.len() as u64) as u32;
    let returned = rng.gen_bool(0.5);
    // Quantity drifts upward along the order-date ramp (±8 jitter,
    // clamped to the spec's [1, 50]); chunk-local min/max stay narrow,
    // which is what lets q6's `< 24` and q19's `<= 30` prune chunks.
    let quantity = ((6.0 + 42.0 * d.order_frac(oi)).round() as i64 + qjit).clamp(1, 50) as f64;
    let price = retail_price((partkey - 1) as usize) * quantity / 10.0;
    let current = Dates::current();
    let rflag = if receipt <= current {
        if returned {
            b'R'
        } else {
            b'A'
        }
    } else {
        b'N'
    };
    let lstatus = if ship > current { b'O' } else { b'F' };
    LineVals {
        partkey,
        suppkey,
        quantity,
        price,
        discount,
        tax,
        ship,
        commit,
        receipt,
        rflag,
        lstatus,
        mode,
        instr,
    }
}

fn count_lineitem_rows(streams: &Streams, n_orders: usize) -> usize {
    (0..n_orders).map(|oi| lines_in_order(streams, oi)).sum()
}

/// Total lineitem rows at this config — an O(orders) prefix scan over
/// the per-order line counts; no table needed.
pub fn lineitem_rows(cfg: &TpchConfig) -> usize {
    let streams = Streams::new(cfg.seed);
    count_lineitem_rows(&streams, cfg.orders())
}

/// Order containing global lineitem row `row`, and that order's first
/// row. `row` must be < the total row count.
fn locate_order(streams: &Streams, row: usize) -> (usize, usize) {
    let (mut oi, mut start) = (0usize, 0usize);
    loop {
        let l = lines_in_order(streams, oi);
        if start + l > row {
            return (oi, start);
        }
        start += l;
        oi += 1;
    }
}

/// One buffer of lineitem rows in column-major form, reused across
/// chunk callbacks. String columns are carried as canonical dictionary
/// codes (see [`SHIP_MODES`] / [`SHIP_INSTRUCTS`] order).
#[derive(Default)]
pub struct LineitemChunk {
    /// Global row index of the first row in the buffer.
    pub lo: usize,
    pub orderkey: Vec<i64>,
    pub partkey: Vec<i64>,
    pub suppkey: Vec<i64>,
    pub linenumber: Vec<i32>,
    pub quantity: Vec<f64>,
    pub extendedprice: Vec<f64>,
    pub discount: Vec<f64>,
    pub tax: Vec<f64>,
    pub returnflag: Vec<u8>,
    pub linestatus: Vec<u8>,
    pub shipdate: Vec<i32>,
    pub commitdate: Vec<i32>,
    pub receiptdate: Vec<i32>,
    pub shipmode: Vec<u32>,
    pub shipinstruct: Vec<u32>,
}

impl LineitemChunk {
    fn with_capacity(n: usize) -> Self {
        Self {
            lo: 0,
            orderkey: Vec::with_capacity(n),
            partkey: Vec::with_capacity(n),
            suppkey: Vec::with_capacity(n),
            linenumber: Vec::with_capacity(n),
            quantity: Vec::with_capacity(n),
            extendedprice: Vec::with_capacity(n),
            discount: Vec::with_capacity(n),
            tax: Vec::with_capacity(n),
            returnflag: Vec::with_capacity(n),
            linestatus: Vec::with_capacity(n),
            shipdate: Vec::with_capacity(n),
            commitdate: Vec::with_capacity(n),
            receiptdate: Vec::with_capacity(n),
            shipmode: Vec::with_capacity(n),
            shipinstruct: Vec::with_capacity(n),
        }
    }

    pub fn len(&self) -> usize {
        self.orderkey.len()
    }

    pub fn is_empty(&self) -> bool {
        self.orderkey.is_empty()
    }

    fn clear(&mut self) {
        self.orderkey.clear();
        self.partkey.clear();
        self.suppkey.clear();
        self.linenumber.clear();
        self.quantity.clear();
        self.extendedprice.clear();
        self.discount.clear();
        self.tax.clear();
        self.returnflag.clear();
        self.linestatus.clear();
        self.shipdate.clear();
        self.commitdate.clear();
        self.receiptdate.clear();
        self.shipmode.clear();
        self.shipinstruct.clear();
    }
}

/// Produce lineitem rows `[lo, hi)` as successive buffers of at most
/// `chunk_rows` rows (only the last may be short), calling `f` after
/// each buffer fills. The one chunk buffer is the only live storage:
/// memory is bounded by `chunk_rows` regardless of scale factor. This
/// is the single chunk-producing core behind [`TpchDb::generate`],
/// [`lineitem_shard`], and streaming consumers.
pub fn for_each_lineitem_chunk<F: FnMut(&LineitemChunk)>(
    cfg: &TpchConfig,
    lo: usize,
    hi: usize,
    chunk_rows: usize,
    mut f: F,
) {
    assert!(chunk_rows > 0, "chunk_rows must be positive");
    if lo >= hi {
        return;
    }
    let streams = Streams::new(cfg.seed);
    let d = Dims::new(cfg);
    let (mut oi, mut order_start) = locate_order(&streams, lo);
    let mut nl = lines_in_order(&streams, oi);
    let mut odate = order_date(&streams, &d, oi);
    let mut chunk = LineitemChunk::with_capacity(chunk_rows.min(hi - lo));
    chunk.lo = lo;
    for r in lo..hi {
        while r >= order_start + nl {
            order_start += nl;
            oi += 1;
            nl = lines_in_order(&streams, oi);
            odate = order_date(&streams, &d, oi);
        }
        let v = line_vals(&streams, &d, r, oi, odate);
        chunk.orderkey.push(oi as i64 + 1);
        chunk.partkey.push(v.partkey);
        chunk.suppkey.push(v.suppkey);
        chunk.linenumber.push((r - order_start) as i32 + 1);
        chunk.quantity.push(v.quantity);
        chunk.extendedprice.push(v.price);
        chunk.discount.push(v.discount);
        chunk.tax.push(v.tax);
        chunk.returnflag.push(v.rflag);
        chunk.linestatus.push(v.lstatus);
        chunk.shipdate.push(v.ship);
        chunk.commitdate.push(v.commit);
        chunk.receiptdate.push(v.receipt);
        chunk.shipmode.push(v.mode);
        chunk.shipinstruct.push(v.instr);
        if chunk.len() == chunk_rows {
            f(&chunk);
            chunk.clear();
            chunk.lo = r + 1;
        }
    }
    if !chunk.is_empty() {
        f(&chunk);
    }
}

/// Column accumulator for lineitem ranges: appends whole chunks and
/// records each chunk's min-max zones as it lands (append-time zone
/// build — no separate whole-column pass).
struct LiCols {
    chunk_rows: usize,
    orderkey: Vec<i64>,
    partkey: Vec<i64>,
    suppkey: Vec<i64>,
    linenumber: Vec<i32>,
    quantity: Vec<f64>,
    extendedprice: Vec<f64>,
    discount: Vec<f64>,
    tax: Vec<f64>,
    returnflag: Vec<u8>,
    linestatus: Vec<u8>,
    shipdate: Vec<i32>,
    commitdate: Vec<i32>,
    receiptdate: Vec<i32>,
    shipmode: Vec<u32>,
    shipinstruct: Vec<u32>,
    z_quantity: Vec<Zone<f64>>,
    z_extendedprice: Vec<Zone<f64>>,
    z_discount: Vec<Zone<f64>>,
    z_tax: Vec<Zone<f64>>,
    z_shipdate: Vec<Zone<i32>>,
    z_commitdate: Vec<Zone<i32>>,
    z_receiptdate: Vec<Zone<i32>>,
}

impl LiCols {
    fn with_capacity(chunk_rows: usize, rows: usize) -> Self {
        let z = rows.div_ceil(chunk_rows.max(1));
        Self {
            chunk_rows,
            orderkey: Vec::with_capacity(rows),
            partkey: Vec::with_capacity(rows),
            suppkey: Vec::with_capacity(rows),
            linenumber: Vec::with_capacity(rows),
            quantity: Vec::with_capacity(rows),
            extendedprice: Vec::with_capacity(rows),
            discount: Vec::with_capacity(rows),
            tax: Vec::with_capacity(rows),
            returnflag: Vec::with_capacity(rows),
            linestatus: Vec::with_capacity(rows),
            shipdate: Vec::with_capacity(rows),
            commitdate: Vec::with_capacity(rows),
            receiptdate: Vec::with_capacity(rows),
            shipmode: Vec::with_capacity(rows),
            shipinstruct: Vec::with_capacity(rows),
            z_quantity: Vec::with_capacity(z),
            z_extendedprice: Vec::with_capacity(z),
            z_discount: Vec::with_capacity(z),
            z_tax: Vec::with_capacity(z),
            z_shipdate: Vec::with_capacity(z),
            z_commitdate: Vec::with_capacity(z),
            z_receiptdate: Vec::with_capacity(z),
        }
    }

    /// Append one produced chunk (at most `chunk_rows` rows) and its
    /// zone entries.
    fn append(&mut self, c: &LineitemChunk) {
        self.orderkey.extend_from_slice(&c.orderkey);
        self.partkey.extend_from_slice(&c.partkey);
        self.suppkey.extend_from_slice(&c.suppkey);
        self.linenumber.extend_from_slice(&c.linenumber);
        self.quantity.extend_from_slice(&c.quantity);
        self.extendedprice.extend_from_slice(&c.extendedprice);
        self.discount.extend_from_slice(&c.discount);
        self.tax.extend_from_slice(&c.tax);
        self.returnflag.extend_from_slice(&c.returnflag);
        self.linestatus.extend_from_slice(&c.linestatus);
        self.shipdate.extend_from_slice(&c.shipdate);
        self.commitdate.extend_from_slice(&c.commitdate);
        self.receiptdate.extend_from_slice(&c.receiptdate);
        self.shipmode.extend_from_slice(&c.shipmode);
        self.shipinstruct.extend_from_slice(&c.shipinstruct);
        self.z_quantity.extend(zones_f64(&c.quantity, self.chunk_rows));
        self.z_extendedprice.extend(zones_f64(&c.extendedprice, self.chunk_rows));
        self.z_discount.extend(zones_f64(&c.discount, self.chunk_rows));
        self.z_tax.extend(zones_f64(&c.tax, self.chunk_rows));
        self.z_shipdate.extend(zones_i32(&c.shipdate, self.chunk_rows));
        self.z_commitdate.extend(zones_i32(&c.commitdate, self.chunk_rows));
        self.z_receiptdate.extend(zones_i32(&c.receiptdate, self.chunk_rows));
    }

    /// Concatenate another accumulator produced for the immediately
    /// following chunk-aligned row range (parallel generation joins
    /// its per-thread parts in order).
    fn merge(&mut self, o: LiCols) {
        self.orderkey.extend(o.orderkey);
        self.partkey.extend(o.partkey);
        self.suppkey.extend(o.suppkey);
        self.linenumber.extend(o.linenumber);
        self.quantity.extend(o.quantity);
        self.extendedprice.extend(o.extendedprice);
        self.discount.extend(o.discount);
        self.tax.extend(o.tax);
        self.returnflag.extend(o.returnflag);
        self.linestatus.extend(o.linestatus);
        self.shipdate.extend(o.shipdate);
        self.commitdate.extend(o.commitdate);
        self.receiptdate.extend(o.receiptdate);
        self.shipmode.extend(o.shipmode);
        self.shipinstruct.extend(o.shipinstruct);
        self.z_quantity.extend(o.z_quantity);
        self.z_extendedprice.extend(o.z_extendedprice);
        self.z_discount.extend(o.z_discount);
        self.z_tax.extend(o.z_tax);
        self.z_shipdate.extend(o.z_shipdate);
        self.z_commitdate.extend(o.z_commitdate);
        self.z_receiptdate.extend(o.z_receiptdate);
    }

    fn into_table(self) -> Table {
        let mut zm = ZoneMap::new(self.chunk_rows);
        zm.add_col("l_quantity", ColZones::F64(self.z_quantity));
        zm.add_col("l_extendedprice", ColZones::F64(self.z_extendedprice));
        zm.add_col("l_discount", ColZones::F64(self.z_discount));
        zm.add_col("l_tax", ColZones::F64(self.z_tax));
        zm.add_col("l_shipdate", ColZones::I32(self.z_shipdate));
        zm.add_col("l_commitdate", ColZones::I32(self.z_commitdate));
        zm.add_col("l_receiptdate", ColZones::I32(self.z_receiptdate));
        let mut li = Table::new("lineitem");
        li.add("l_orderkey", Column::I64(self.orderkey));
        li.add("l_partkey", Column::I64(self.partkey));
        li.add("l_suppkey", Column::I64(self.suppkey));
        li.add("l_linenumber", Column::I32(self.linenumber));
        li.add("l_quantity", Column::F64(self.quantity));
        li.add("l_extendedprice", Column::F64(self.extendedprice));
        li.add("l_discount", Column::F64(self.discount));
        li.add("l_tax", Column::F64(self.tax));
        li.add("l_returnflag", Column::U8(self.returnflag));
        li.add("l_linestatus", Column::U8(self.linestatus));
        li.add("l_shipdate", Column::I32(self.shipdate));
        li.add("l_commitdate", Column::I32(self.commitdate));
        li.add("l_receiptdate", Column::I32(self.receiptdate));
        li.add("l_shipmode", Column::Str { dict: dict_strings(&SHIP_MODES), codes: self.shipmode });
        li.add(
            "l_shipinstruct",
            Column::Str { dict: dict_strings(&SHIP_INSTRUCTS), codes: self.shipinstruct },
        );
        li.set_zones(zm);
        li
    }
}

/// Generate lineitem rows `[lo, hi)` as a table with a local zone map
/// (chunked from the shard's row 0). This is the worker path: the
/// distributed coordinator generates each partition in place instead
/// of shipping table bytes, and the result is bitwise identical to
/// rows `[lo, hi)` of [`TpchDb::generate`]'s lineitem.
pub fn lineitem_shard(cfg: &TpchConfig, lo: usize, hi: usize) -> Table {
    let mut cols = LiCols::with_capacity(CHUNK_ROWS, hi.saturating_sub(lo));
    for_each_lineitem_chunk(cfg, lo, hi, CHUNK_ROWS, |c| cols.append(c));
    cols.into_table()
}

/// Full lineitem, generated in parallel: each thread produces a
/// chunk-aligned contiguous row range through the same chunk core,
/// and the parts concatenate in order (so thread count never changes
/// the data, and per-thread zones concatenate to the global map).
fn gen_lineitem_parallel(cfg: &TpchConfig, total: usize) -> Table {
    let chunks = total.div_ceil(CHUNK_ROWS).max(1);
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(chunks);
    let chunks_per = chunks.div_ceil(threads);
    let parts: Vec<LiCols> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let lo = (t * chunks_per * CHUNK_ROWS).min(total);
                let hi = ((t + 1) * chunks_per * CHUNK_ROWS).min(total);
                s.spawn(move || {
                    let mut cols = LiCols::with_capacity(CHUNK_ROWS, hi - lo);
                    for_each_lineitem_chunk(cfg, lo, hi, CHUNK_ROWS, |c| cols.append(c));
                    cols
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("generator thread panicked")).collect()
    });
    let mut all = LiCols::with_capacity(CHUNK_ROWS, total);
    for p in parts {
        all.merge(p);
    }
    all.into_table()
}

// --------------------------------------------------------- other tables

fn dict_strings(words: &[&str]) -> Vec<String> {
    words.iter().map(|s| s.to_string()).collect()
}

/// Orders, generated in parallel over order ranges. o_totalprice and
/// o_orderstatus re-derive the order's lines through [`line_vals`], so
/// they stay consistent with lineitem without materializing it.
fn gen_orders_parallel(cfg: &TpchConfig, streams: &Streams, d: &Dims) -> Table {
    let n = d.n_orders;
    let threads = std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1).min(n);
    let per = n.div_ceil(threads);
    struct OCols {
        orderkey: Vec<i64>,
        custkey: Vec<i64>,
        orderdate: Vec<i32>,
        totalprice: Vec<f64>,
        priority: Vec<u32>,
        status: Vec<u8>,
    }
    let parts: Vec<OCols> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let alo = (t * per).min(n);
                let ahi = ((t + 1) * per).min(n);
                s.spawn(move || {
                    let m = ahi - alo;
                    let mut o = OCols {
                        orderkey: Vec::with_capacity(m),
                        custkey: Vec::with_capacity(m),
                        orderdate: Vec::with_capacity(m),
                        totalprice: Vec::with_capacity(m),
                        priority: Vec::with_capacity(m),
                        status: Vec::with_capacity(m),
                    };
                    // First global lineitem row of order `alo`.
                    let mut row = (0..alo).map(|oi| lines_in_order(streams, oi)).sum::<usize>();
                    for oi in alo..ahi {
                        let mut rng = row_rng(streams.order, oi);
                        let custkey = rng.gen_range_i64(1, d.n_cust);
                        let prio = rng.gen_range_u64(PRIORITIES.len() as u64) as u32;
                        let odate = order_date(streams, d, oi);
                        let nl = lines_in_order(streams, oi);
                        let mut total = 0.0;
                        let mut all_f = true;
                        for ln in 0..nl {
                            let v = line_vals(streams, d, row + ln, oi, odate);
                            total += v.price * (1.0 - v.discount) * (1.0 + v.tax);
                            if v.lstatus == b'O' {
                                all_f = false;
                            }
                        }
                        row += nl;
                        o.orderkey.push(oi as i64 + 1);
                        o.custkey.push(custkey);
                        o.orderdate.push(odate);
                        o.totalprice.push(total);
                        o.priority.push(prio);
                        o.status.push(if all_f { b'F' } else { b'O' });
                    }
                    o
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("generator thread panicked")).collect()
    });
    let mut orderkey = Vec::with_capacity(n);
    let mut custkey = Vec::with_capacity(n);
    let mut orderdate = Vec::with_capacity(n);
    let mut totalprice = Vec::with_capacity(n);
    let mut priority = Vec::with_capacity(n);
    let mut status = Vec::with_capacity(n);
    for p in parts {
        orderkey.extend(p.orderkey);
        custkey.extend(p.custkey);
        orderdate.extend(p.orderdate);
        totalprice.extend(p.totalprice);
        priority.extend(p.priority);
        status.extend(p.status);
    }
    let mut t = Table::new("orders");
    t.add("o_orderkey", Column::I64(orderkey));
    t.add("o_custkey", Column::I64(custkey));
    t.add("o_orderdate", Column::I32(orderdate));
    t.add("o_totalprice", Column::F64(totalprice));
    t.add("o_orderpriority", Column::Str { dict: dict_strings(&PRIORITIES), codes: priority });
    t.add("o_orderstatus", Column::U8(status));
    t
}

fn part_name_dict() -> Vec<String> {
    let mut d = Vec::with_capacity(COLORS.len() * COLORS.len());
    for a in COLORS {
        for b in COLORS {
            d.push(format!("{a} {b}"));
        }
    }
    d
}

fn brand_dict() -> Vec<String> {
    let mut d = Vec::with_capacity(25);
    for m in 1..=5 {
        for nn in 1..=5 {
            d.push(format!("Brand#{m}{nn}"));
        }
    }
    d
}

fn type_dict() -> Vec<String> {
    let mut d = Vec::with_capacity(TYPE_SYLL1.len() * TYPE_SYLL2.len() * TYPE_SYLL3.len());
    for a in TYPE_SYLL1 {
        for b in TYPE_SYLL2 {
            for c in TYPE_SYLL3 {
                d.push(format!("{a} {b} {c}"));
            }
        }
    }
    d
}

fn gen_part(cfg: &TpchConfig, streams: &Streams) -> Table {
    let n = cfg.parts();
    let mut partkey = Vec::with_capacity(n);
    let mut name = Vec::with_capacity(n);
    let mut brand = Vec::with_capacity(n);
    let mut ptype = Vec::with_capacity(n);
    let mut container = Vec::with_capacity(n);
    let mut size = Vec::with_capacity(n);
    let mut retail = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = row_rng(streams.part, i);
        partkey.push(i as i64 + 1);
        // Name: two color words (Q9 greps a color substring). Codes
        // index the canonical COLORS×COLORS dictionary directly.
        let c1 = rng.gen_range_u64(COLORS.len() as u64) as u32;
        let c2 = rng.gen_range_u64(COLORS.len() as u64) as u32;
        name.push(c1 * COLORS.len() as u32 + c2);
        let m = rng.gen_range_u64(5) as u32;
        let nn = rng.gen_range_u64(5) as u32;
        brand.push(m * 5 + nn);
        let t1 = rng.gen_range_u64(TYPE_SYLL1.len() as u64) as u32;
        let t2 = rng.gen_range_u64(TYPE_SYLL2.len() as u64) as u32;
        let t3 = rng.gen_range_u64(TYPE_SYLL3.len() as u64) as u32;
        let syl23 = (TYPE_SYLL2.len() * TYPE_SYLL3.len()) as u32;
        ptype.push(t1 * syl23 + t2 * TYPE_SYLL3.len() as u32 + t3);
        container.push(rng.gen_range_u64(CONTAINERS.len() as u64) as u32);
        size.push(rng.gen_range_i64(1, 50) as i32);
        retail.push(retail_price(i));
    }
    let mut t = Table::new("part");
    t.add("p_partkey", Column::I64(partkey));
    t.add("p_name", Column::Str { dict: part_name_dict(), codes: name });
    t.add("p_brand", Column::Str { dict: brand_dict(), codes: brand });
    t.add("p_type", Column::Str { dict: type_dict(), codes: ptype });
    t.add("p_container", Column::Str { dict: dict_strings(&CONTAINERS), codes: container });
    t.add("p_size", Column::I32(size));
    t.add("p_retailprice", Column::F64(retail));
    t
}

fn gen_supplier(cfg: &TpchConfig, streams: &Streams) -> Table {
    let n = cfg.suppliers();
    let mut suppkey = Vec::with_capacity(n);
    let mut nationkey = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = row_rng(streams.supplier, i);
        suppkey.push(i as i64 + 1);
        nationkey.push(rng.gen_range_u64(25) as i32);
        acctbal.push(rng.gen_range_f64(-999.99, 9999.99));
    }
    let mut t = Table::new("supplier");
    t.add("s_suppkey", Column::I64(suppkey));
    t.add("s_nationkey", Column::I32(nationkey));
    t.add("s_acctbal", Column::F64(acctbal));
    t
}

fn gen_partsupp(cfg: &TpchConfig, streams: &Streams) -> Table {
    let parts = cfg.parts();
    let sups = cfg.suppliers() as i64;
    // min() guards tiny scale factors where fewer than 4 suppliers exist.
    let per_part = SUPPLIERS_PER_PART.min(sups as usize);
    // Stride chosen so j·step are distinct mod `sups` for j < per_part
    // (the spec's formula, simplified: step < sups/3 or step = 1).
    let step = (sups / 4).max(1);
    let n = parts * per_part;
    let mut partkey = Vec::with_capacity(n);
    let mut suppkey = Vec::with_capacity(n);
    let mut availqty = Vec::with_capacity(n);
    let mut supplycost = Vec::with_capacity(n);
    for p in 0..parts {
        for j in 0..per_part {
            let r = p * per_part + j;
            let mut rng = row_rng(streams.partsupp, r);
            partkey.push(p as i64 + 1);
            let s = (p as i64 + j as i64 * step) % sups + 1;
            suppkey.push(s);
            availqty.push(rng.gen_range_i64(1, 9999) as i32);
            supplycost.push(rng.gen_range_f64(1.0, 1000.0));
        }
    }
    let mut t = Table::new("partsupp");
    t.add("ps_partkey", Column::I64(partkey));
    t.add("ps_suppkey", Column::I64(suppkey));
    t.add("ps_availqty", Column::I32(availqty));
    t.add("ps_supplycost", Column::F64(supplycost));
    t
}

fn gen_customer(cfg: &TpchConfig, streams: &Streams) -> Table {
    let n = cfg.customers();
    let mut custkey = Vec::with_capacity(n);
    let mut nationkey = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    let mut segment = Vec::with_capacity(n);
    for i in 0..n {
        let mut rng = row_rng(streams.customer, i);
        custkey.push(i as i64 + 1);
        nationkey.push(rng.gen_range_u64(25) as i32);
        acctbal.push(rng.gen_range_f64(-999.99, 9999.99));
        segment.push(rng.gen_range_u64(SEGMENTS.len() as u64) as u32);
    }
    let mut t = Table::new("customer");
    t.add("c_custkey", Column::I64(custkey));
    t.add("c_nationkey", Column::I32(nationkey));
    t.add("c_acctbal", Column::F64(acctbal));
    t.add("c_mktsegment", Column::Str { dict: dict_strings(&SEGMENTS), codes: segment });
    t
}

fn gen_nation_region() -> (Table, Table) {
    let mut n_key = Vec::new();
    let mut n_name = StrColumnBuilder::new();
    let mut n_region = Vec::new();
    for (i, (name, region)) in NATIONS.iter().enumerate() {
        n_key.push(i as i64);
        n_name.push(name);
        n_region.push(*region as i32);
    }
    let mut nation = Table::new("nation");
    nation.add("n_nationkey", Column::I64(n_key));
    nation.add("n_name", n_name.finish());
    nation.add("n_regionkey", Column::I32(n_region));

    let mut r_key = Vec::new();
    let mut r_name = StrColumnBuilder::new();
    for (i, name) in REGIONS.iter().enumerate() {
        r_key.push(i as i64);
        r_name.push(name);
    }
    let mut region = Table::new("region");
    region.add("r_regionkey", Column::I64(r_key));
    region.add("r_name", r_name.finish());
    (nation, region)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TpchDb {
        TpchDb::generate(TpchConfig::new(0.001, 42))
    }

    #[test]
    fn cardinalities_scale() {
        let db = small();
        assert_eq!(db.orders.len(), 1500);
        assert_eq!(db.customer.len(), 150);
        assert_eq!(db.part.len(), 200);
        assert_eq!(db.supplier.len(), 10);
        assert_eq!(db.partsupp.len(), 800);
        assert_eq!(db.nation.len(), 25);
        assert_eq!(db.region.len(), 5);
        // 1-7 lines per order, mean ≈ 4.
        let ratio = db.lineitem.len() as f64 / db.orders.len() as f64;
        assert!(ratio > 3.5 && ratio < 4.5, "lines/order = {ratio}");
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.lineitem.len(), b.lineitem.len());
        assert_eq!(
            a.lineitem.col("l_extendedprice").as_f64()[..50],
            b.lineitem.col("l_extendedprice").as_f64()[..50]
        );
        assert_eq!(
            a.orders.col("o_orderdate").as_i32()[..50],
            b.orders.col("o_orderdate").as_i32()[..50]
        );
    }

    #[test]
    fn different_seeds_differ() {
        let a = TpchDb::generate(TpchConfig::new(0.001, 1));
        let b = TpchDb::generate(TpchConfig::new(0.001, 2));
        assert_ne!(
            a.lineitem.col("l_quantity").as_f64()[..20],
            b.lineitem.col("l_quantity").as_f64()[..20]
        );
    }

    #[test]
    fn value_domains() {
        let db = small();
        let li = &db.lineitem;
        for &q in li.col("l_quantity").as_f64() {
            assert!((1.0..=50.0).contains(&q));
        }
        for &d in li.col("l_discount").as_f64() {
            assert!((0.0..=0.10).contains(&d));
        }
        for &t in li.col("l_tax").as_f64() {
            assert!((0.0..=0.08).contains(&t));
        }
        for &f in li.col("l_returnflag").as_u8() {
            assert!(f == b'R' || f == b'A' || f == b'N');
        }
        for &s in li.col("l_linestatus").as_u8() {
            assert!(s == b'O' || s == b'F');
        }
    }

    #[test]
    fn date_consistency() {
        let db = small();
        let li = &db.lineitem;
        let ship = li.col("l_shipdate").as_i32();
        let receipt = li.col("l_receiptdate").as_i32();
        let ok = li.col("l_orderkey").as_i64();
        let odate = db.orders.col("o_orderdate").as_i32();
        for i in 0..li.len() {
            assert!(receipt[i] > ship[i]);
            let o = (ok[i] - 1) as usize;
            assert!(ship[i] > odate[o]);
            assert!(ship[i] <= odate[o] + 121);
        }
    }

    #[test]
    fn orderdates_within_range() {
        let db = small();
        let (start, end) = (Dates::start(), Dates::end_orders());
        for &d in db.orders.col("o_orderdate").as_i32() {
            assert!(d >= start && d <= end);
        }
    }

    #[test]
    fn foreign_keys_resolve() {
        let db = small();
        let n_parts = db.part.len() as i64;
        let n_sups = db.supplier.len() as i64;
        let n_orders = db.orders.len() as i64;
        let n_cust = db.customer.len() as i64;
        for &pk in db.lineitem.col("l_partkey").as_i64() {
            assert!(pk >= 1 && pk <= n_parts);
        }
        for &sk in db.lineitem.col("l_suppkey").as_i64() {
            assert!(sk >= 1 && sk <= n_sups);
        }
        for &ok in db.lineitem.col("l_orderkey").as_i64() {
            assert!(ok >= 1 && ok <= n_orders);
        }
        for &ck in db.orders.col("o_custkey").as_i64() {
            assert!(ck >= 1 && ck <= n_cust);
        }
        for &nk in db.customer.col("c_nationkey").as_i32() {
            assert!((0..25).contains(&nk));
        }
    }

    #[test]
    fn returnflag_respects_current_date() {
        let db = small();
        let li = &db.lineitem;
        let receipt = li.col("l_receiptdate").as_i32();
        let flag = li.col("l_returnflag").as_u8();
        let cur = Dates::current();
        for i in 0..li.len() {
            if receipt[i] <= cur {
                assert!(flag[i] == b'R' || flag[i] == b'A');
            } else {
                assert_eq!(flag[i], b'N');
            }
        }
    }

    #[test]
    fn sf1_scale_bytes_near_1gb() {
        // Don't generate SF 1 in tests; check the arithmetic at SF 0.01.
        let db = TpchDb::generate(TpchConfig::new(0.01, 7));
        let bytes = db.bytes();
        // SF 0.01 ≈ 10 MB raw (ours is leaner than dbgen's ~10.5 MB with
        // comments/strings omitted; accept a broad band).
        assert!(bytes > 3_000_000 && bytes < 20_000_000, "bytes={bytes}");
    }

    #[test]
    fn partsupp_pairs_unique() {
        let db = small();
        let pk = db.partsupp.col("ps_partkey").as_i64();
        let sk = db.partsupp.col("ps_suppkey").as_i64();
        let mut seen = std::collections::HashSet::new();
        for i in 0..db.partsupp.len() {
            assert!(seen.insert((pk[i], sk[i])), "dup pair ({}, {})", pk[i], sk[i]);
        }
    }

    // ----------------------------------------- streaming / shard tests

    /// Shard rows must be bitwise identical to the same rows of the
    /// full generation — the property the coordinator's generate-in-
    /// place worker path rests on.
    fn assert_is_slice(full: &Table, shard: &Table, lo: usize) {
        for name in full.column_names() {
            let hi = lo + shard.len();
            match (full.col(name), shard.col(name)) {
                (Column::I64(a), Column::I64(b)) => assert_eq!(&a[lo..hi], &b[..], "{name}"),
                (Column::I32(a), Column::I32(b)) => assert_eq!(&a[lo..hi], &b[..], "{name}"),
                (Column::F64(a), Column::F64(b)) => assert_eq!(&a[lo..hi], &b[..], "{name}"),
                (Column::U8(a), Column::U8(b)) => assert_eq!(&a[lo..hi], &b[..], "{name}"),
                (
                    Column::Str { dict: da, codes: ca },
                    Column::Str { dict: db, codes: cb },
                ) => {
                    assert_eq!(da, db, "{name} dictionaries diverge");
                    assert_eq!(&ca[lo..hi], &cb[..], "{name}");
                }
                _ => panic!("column {name} type mismatch"),
            }
        }
    }

    #[test]
    fn shard_matches_slice_of_full_generation() {
        let cfg = TpchConfig::new(0.001, 42);
        let db = TpchDb::generate(cfg);
        let n = db.lineitem.len();
        assert_eq!(n, lineitem_rows(&cfg));
        for (lo, hi) in
            [(0, n), (0, 1), (1, 1000), (n / 3, 2 * n / 3), (n - 7, n), (4096 - 13, 4096 + 13)]
        {
            let shard = lineitem_shard(&cfg, lo, hi);
            assert_eq!(shard.len(), hi - lo);
            assert_is_slice(&db.lineitem, &shard, lo);
            assert!(shard.zones().is_some(), "shards carry local zone maps");
        }
    }

    #[test]
    fn lineitem_zone_map_bounds_every_chunk() {
        let db = small();
        let zm = db.lineitem.zones().expect("lineitem must carry zones");
        assert_eq!(zm.chunk_rows(), CHUNK_ROWS);
        assert_eq!(zm.chunks(), db.lineitem.len().div_ceil(CHUNK_ROWS));
        let ship = db.lineitem.col("l_shipdate").as_i32();
        match zm.col("l_shipdate").expect("shipdate zones") {
            ColZones::I32(zs) => {
                for (ci, z) in zs.iter().enumerate() {
                    let s = ci * CHUNK_ROWS;
                    let e = (s + CHUNK_ROWS).min(ship.len());
                    for &v in &ship[s..e] {
                        assert!(z.min <= v && v <= z.max);
                    }
                }
            }
            _ => panic!("shipdate zones must be i32"),
        }
        match zm.col("l_quantity").expect("quantity zones") {
            ColZones::F64(zs) => assert_eq!(zs.len(), zm.chunks()),
            _ => panic!("quantity zones must be f64"),
        }
    }

    #[test]
    fn streaming_chunks_are_bounded_and_complete() {
        let cfg = TpchConfig::new(0.001, 42);
        let total = lineitem_rows(&cfg);
        let mut rows = 0;
        let mut next_lo = 0;
        for_each_lineitem_chunk(&cfg, 0, total, 1000, |c| {
            assert!(!c.is_empty() && c.len() <= 1000);
            assert_eq!(c.lo, next_lo);
            next_lo += c.len();
            rows += c.len();
        });
        assert_eq!(rows, total);
    }

    #[test]
    fn order_dates_ramp_with_bounded_jitter() {
        let db = small();
        let od = db.orders.col("o_orderdate").as_i32();
        for w in od.windows(2) {
            assert!(w[1] >= w[0] - 31, "jitter exceeded the ramp bound");
        }
        assert!(od[od.len() - 1] - od[0] > 2000, "dates must span the full range");
    }

    #[test]
    fn quantity_drifts_with_order_position() {
        let db = small();
        let q = db.lineitem.col("l_quantity").as_f64();
        let k = q.len() / 10;
        let head: f64 = q[..k].iter().sum::<f64>() / k as f64;
        let tail: f64 = q[q.len() - k..].iter().sum::<f64>() / k as f64;
        assert!(tail > head + 20.0, "quantity drift too weak: head={head} tail={tail}");
    }
}

//! Deterministic TPC-H data generator (`dbgen` replacement).
//!
//! Generates the eight TPC-H tables at an arbitrary scale factor with the
//! distributions the benchmark queries depend on (date ranges, discount
//! and quantity ranges, 1–7 lines per order, segment/mode/priority value
//! sets, color-word part names). Everything is derived from a single seed
//! via per-table PRNG streams, so two calls with the same `(sf, seed)`
//! produce identical data — a property the distributed coordinator relies
//! on (workers regenerate their partition instead of shipping it).

use super::*;
use crate::analytics::column::{date_to_days, Column, StrColumnBuilder, Table};
use crate::prng::Pcg64;

/// Generator configuration.
#[derive(Clone, Copy, Debug)]
pub struct TpchConfig {
    /// Scale factor: 1.0 ≈ 1 GB of raw data (6M lineitems).
    pub sf: f64,
    pub seed: u64,
}

impl TpchConfig {
    pub fn new(sf: f64, seed: u64) -> Self {
        assert!(sf > 0.0);
        Self { sf, seed }
    }

    pub fn orders(&self) -> usize {
        ((SF1_ORDERS as f64 * self.sf) as usize).max(10)
    }
    pub fn customers(&self) -> usize {
        ((SF1_CUSTOMER as f64 * self.sf) as usize).max(5)
    }
    pub fn parts(&self) -> usize {
        ((SF1_PART as f64 * self.sf) as usize).max(5)
    }
    pub fn suppliers(&self) -> usize {
        ((SF1_SUPPLIER as f64 * self.sf) as usize).max(3)
    }
}

/// The generated database.
pub struct TpchDb {
    pub config: TpchConfig,
    pub lineitem: Table,
    pub orders: Table,
    pub customer: Table,
    pub part: Table,
    pub supplier: Table,
    pub partsupp: Table,
    pub nation: Table,
    pub region: Table,
}

/// TPC-H date constants (days since unix epoch).
pub struct Dates;
impl Dates {
    pub fn start() -> i32 {
        date_to_days(1992, 1, 1)
    }
    /// Last order date: 1998-08-02.
    pub fn end_orders() -> i32 {
        date_to_days(1998, 8, 2)
    }
    /// The returnflag pivot: 1995-06-17.
    pub fn current() -> i32 {
        date_to_days(1995, 6, 17)
    }
}

impl TpchDb {
    /// Generate the full database.
    pub fn generate(config: TpchConfig) -> Self {
        let root = Pcg64::seed_from_u64(config.seed);
        let part = gen_part(&config, &mut root.derive("part"));
        let supplier = gen_supplier(&config, &mut root.derive("supplier"));
        let partsupp = gen_partsupp(&config, &mut root.derive("partsupp"));
        let customer = gen_customer(&config, &mut root.derive("customer"));
        let (orders, lineitem) =
            gen_orders_lineitem(&config, &mut root.derive("orders"), &part);
        let (nation, region) = gen_nation_region();
        Self { config, lineitem, orders, customer, part, supplier, partsupp, nation, region }
    }

    /// Total raw bytes across tables.
    pub fn bytes(&self) -> u64 {
        self.lineitem.bytes()
            + self.orders.bytes()
            + self.customer.bytes()
            + self.part.bytes()
            + self.supplier.bytes()
            + self.partsupp.bytes()
            + self.nation.bytes()
            + self.region.bytes()
    }
}

fn gen_part(cfg: &TpchConfig, rng: &mut Pcg64) -> Table {
    let n = cfg.parts();
    let mut partkey = Vec::with_capacity(n);
    let mut name = StrColumnBuilder::new();
    let mut brand = StrColumnBuilder::new();
    let mut ptype = StrColumnBuilder::new();
    let mut container = StrColumnBuilder::new();
    let mut size = Vec::with_capacity(n);
    let mut retail = Vec::with_capacity(n);
    for i in 0..n {
        partkey.push(i as i64 + 1);
        // Name: two distinct color words (Q9 greps a color substring).
        let c1 = COLORS[rng.gen_range_u64(COLORS.len() as u64) as usize];
        let c2 = COLORS[rng.gen_range_u64(COLORS.len() as u64) as usize];
        name.push(&format!("{c1} {c2}"));
        let m = rng.gen_range_u64(5) + 1;
        let nn = rng.gen_range_u64(5) + 1;
        brand.push(&format!("Brand#{m}{nn}"));
        let t = format!(
            "{} {} {}",
            TYPE_SYLL1[rng.gen_range_u64(TYPE_SYLL1.len() as u64) as usize],
            TYPE_SYLL2[rng.gen_range_u64(TYPE_SYLL2.len() as u64) as usize],
            TYPE_SYLL3[rng.gen_range_u64(TYPE_SYLL3.len() as u64) as usize],
        );
        ptype.push(&t);
        container.push(CONTAINERS[rng.gen_range_u64(CONTAINERS.len() as u64) as usize]);
        size.push(rng.gen_range_i64(1, 50) as i32);
        // retailprice formula shape from the spec.
        retail.push(900.0 + (i as f64 % 1000.0) / 10.0 + (i % 100) as f64);
    }
    let mut t = Table::new("part");
    t.add("p_partkey", Column::I64(partkey));
    t.add("p_name", name.finish());
    t.add("p_brand", brand.finish());
    t.add("p_type", ptype.finish());
    t.add("p_container", container.finish());
    t.add("p_size", Column::I32(size));
    t.add("p_retailprice", Column::F64(retail));
    t
}

fn gen_supplier(cfg: &TpchConfig, rng: &mut Pcg64) -> Table {
    let n = cfg.suppliers();
    let mut suppkey = Vec::with_capacity(n);
    let mut nationkey = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    for i in 0..n {
        suppkey.push(i as i64 + 1);
        nationkey.push(rng.gen_range_u64(25) as i32);
        acctbal.push(rng.gen_range_f64(-999.99, 9999.99));
    }
    let mut t = Table::new("supplier");
    t.add("s_suppkey", Column::I64(suppkey));
    t.add("s_nationkey", Column::I32(nationkey));
    t.add("s_acctbal", Column::F64(acctbal));
    t
}

fn gen_partsupp(cfg: &TpchConfig, rng: &mut Pcg64) -> Table {
    let parts = cfg.parts();
    let sups = cfg.suppliers() as i64;
    // min() guards tiny scale factors where fewer than 4 suppliers exist.
    let per_part = SUPPLIERS_PER_PART.min(sups as usize);
    // Stride chosen so j·step are distinct mod `sups` for j < per_part
    // (the spec's formula, simplified: step < sups/3 or step = 1).
    let step = (sups / 4).max(1);
    let n = parts * per_part;
    let mut partkey = Vec::with_capacity(n);
    let mut suppkey = Vec::with_capacity(n);
    let mut availqty = Vec::with_capacity(n);
    let mut supplycost = Vec::with_capacity(n);
    for p in 0..parts {
        for j in 0..per_part {
            partkey.push(p as i64 + 1);
            let s = (p as i64 + j as i64 * step) % sups + 1;
            suppkey.push(s);
            availqty.push(rng.gen_range_i64(1, 9999) as i32);
            supplycost.push(rng.gen_range_f64(1.0, 1000.0));
        }
    }
    let mut t = Table::new("partsupp");
    t.add("ps_partkey", Column::I64(partkey));
    t.add("ps_suppkey", Column::I64(suppkey));
    t.add("ps_availqty", Column::I32(availqty));
    t.add("ps_supplycost", Column::F64(supplycost));
    t
}

fn gen_customer(cfg: &TpchConfig, rng: &mut Pcg64) -> Table {
    let n = cfg.customers();
    let mut custkey = Vec::with_capacity(n);
    let mut nationkey = Vec::with_capacity(n);
    let mut acctbal = Vec::with_capacity(n);
    let mut segment = StrColumnBuilder::new();
    for i in 0..n {
        custkey.push(i as i64 + 1);
        nationkey.push(rng.gen_range_u64(25) as i32);
        acctbal.push(rng.gen_range_f64(-999.99, 9999.99));
        segment.push(SEGMENTS[rng.gen_range_u64(SEGMENTS.len() as u64) as usize]);
    }
    let mut t = Table::new("customer");
    t.add("c_custkey", Column::I64(custkey));
    t.add("c_nationkey", Column::I32(nationkey));
    t.add("c_acctbal", Column::F64(acctbal));
    t.add("c_mktsegment", segment.finish());
    t
}

fn gen_orders_lineitem(cfg: &TpchConfig, rng: &mut Pcg64, part: &Table) -> (Table, Table) {
    let n_orders = cfg.orders();
    let n_cust = cfg.customers() as i64;
    let n_parts = cfg.parts() as i64;
    let n_sups = cfg.suppliers() as i64;
    let retail = part.col("p_retailprice").as_f64();

    let start = Dates::start();
    let end = Dates::end_orders();
    let current = Dates::current();

    // orders columns
    let mut o_orderkey = Vec::with_capacity(n_orders);
    let mut o_custkey = Vec::with_capacity(n_orders);
    let mut o_orderdate = Vec::with_capacity(n_orders);
    let mut o_totalprice = Vec::with_capacity(n_orders);
    let mut o_priority = StrColumnBuilder::new();
    let mut o_status = Vec::with_capacity(n_orders);

    // lineitem columns (≈ 4 lines/order average)
    let est = n_orders * 4;
    let mut l_orderkey = Vec::with_capacity(est);
    let mut l_partkey = Vec::with_capacity(est);
    let mut l_suppkey = Vec::with_capacity(est);
    let mut l_linenumber = Vec::with_capacity(est);
    let mut l_quantity = Vec::with_capacity(est);
    let mut l_extendedprice = Vec::with_capacity(est);
    let mut l_discount = Vec::with_capacity(est);
    let mut l_tax = Vec::with_capacity(est);
    let mut l_returnflag = Vec::with_capacity(est);
    let mut l_linestatus = Vec::with_capacity(est);
    let mut l_shipdate = Vec::with_capacity(est);
    let mut l_commitdate = Vec::with_capacity(est);
    let mut l_receiptdate = Vec::with_capacity(est);
    let mut l_shipmode = StrColumnBuilder::new();
    let mut l_shipinstruct = StrColumnBuilder::new();

    for i in 0..n_orders {
        let orderkey = i as i64 + 1;
        let orderdate = rng.gen_range_i64(start as i64, end as i64) as i32;
        o_orderkey.push(orderkey);
        o_custkey.push(rng.gen_range_i64(1, n_cust));
        o_orderdate.push(orderdate);
        o_priority.push(PRIORITIES[rng.gen_range_u64(PRIORITIES.len() as u64) as usize]);

        let lines = rng.gen_range_i64(1, 7);
        let mut total = 0.0;
        let mut all_f = true;
        for ln in 0..lines {
            let partkey = rng.gen_range_i64(1, n_parts);
            let suppkey = rng.gen_range_i64(1, n_sups);
            let quantity = rng.gen_range_i64(1, 50) as f64;
            let price = retail[(partkey - 1) as usize] * quantity / 10.0;
            let discount = rng.gen_range_i64(0, 10) as f64 / 100.0;
            let tax = rng.gen_range_i64(0, 8) as f64 / 100.0;
            let shipdate = orderdate + rng.gen_range_i64(1, 121) as i32;
            let commitdate = orderdate + rng.gen_range_i64(30, 90) as i32;
            let receiptdate = shipdate + rng.gen_range_i64(1, 30) as i32;
            let returnflag = if receiptdate <= current {
                if rng.gen_bool(0.5) {
                    b'R'
                } else {
                    b'A'
                }
            } else {
                b'N'
            };
            let linestatus = if shipdate > current { b'O' } else { b'F' };
            if linestatus == b'O' {
                all_f = false;
            }
            l_orderkey.push(orderkey);
            l_partkey.push(partkey);
            l_suppkey.push(suppkey);
            l_linenumber.push(ln as i32 + 1);
            l_quantity.push(quantity);
            l_extendedprice.push(price);
            l_discount.push(discount);
            l_tax.push(tax);
            l_returnflag.push(returnflag);
            l_linestatus.push(linestatus);
            l_shipdate.push(shipdate);
            l_commitdate.push(commitdate);
            l_receiptdate.push(receiptdate);
            l_shipmode.push(SHIP_MODES[rng.gen_range_u64(SHIP_MODES.len() as u64) as usize]);
            l_shipinstruct
                .push(SHIP_INSTRUCTS[rng.gen_range_u64(SHIP_INSTRUCTS.len() as u64) as usize]);
            total += price * (1.0 - discount) * (1.0 + tax);
        }
        o_totalprice.push(total);
        o_status.push(if all_f { b'F' } else { b'O' });
    }

    let mut orders = Table::new("orders");
    orders.add("o_orderkey", Column::I64(o_orderkey));
    orders.add("o_custkey", Column::I64(o_custkey));
    orders.add("o_orderdate", Column::I32(o_orderdate));
    orders.add("o_totalprice", Column::F64(o_totalprice));
    orders.add("o_orderpriority", o_priority.finish());
    orders.add("o_orderstatus", Column::U8(o_status));

    let mut li = Table::new("lineitem");
    li.add("l_orderkey", Column::I64(l_orderkey));
    li.add("l_partkey", Column::I64(l_partkey));
    li.add("l_suppkey", Column::I64(l_suppkey));
    li.add("l_linenumber", Column::I32(l_linenumber));
    li.add("l_quantity", Column::F64(l_quantity));
    li.add("l_extendedprice", Column::F64(l_extendedprice));
    li.add("l_discount", Column::F64(l_discount));
    li.add("l_tax", Column::F64(l_tax));
    li.add("l_returnflag", Column::U8(l_returnflag));
    li.add("l_linestatus", Column::U8(l_linestatus));
    li.add("l_shipdate", Column::I32(l_shipdate));
    li.add("l_commitdate", Column::I32(l_commitdate));
    li.add("l_receiptdate", Column::I32(l_receiptdate));
    li.add("l_shipmode", l_shipmode.finish());
    li.add("l_shipinstruct", l_shipinstruct.finish());
    (orders, li)
}

fn gen_nation_region() -> (Table, Table) {
    let mut n_key = Vec::new();
    let mut n_name = StrColumnBuilder::new();
    let mut n_region = Vec::new();
    for (i, (name, region)) in NATIONS.iter().enumerate() {
        n_key.push(i as i64);
        n_name.push(name);
        n_region.push(*region as i32);
    }
    let mut nation = Table::new("nation");
    nation.add("n_nationkey", Column::I64(n_key));
    nation.add("n_name", n_name.finish());
    nation.add("n_regionkey", Column::I32(n_region));

    let mut r_key = Vec::new();
    let mut r_name = StrColumnBuilder::new();
    for (i, name) in REGIONS.iter().enumerate() {
        r_key.push(i as i64);
        r_name.push(name);
    }
    let mut region = Table::new("region");
    region.add("r_regionkey", Column::I64(r_key));
    region.add("r_name", r_name.finish());
    (nation, region)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TpchDb {
        TpchDb::generate(TpchConfig::new(0.001, 42))
    }

    #[test]
    fn cardinalities_scale() {
        let db = small();
        assert_eq!(db.orders.len(), 1500);
        assert_eq!(db.customer.len(), 150);
        assert_eq!(db.part.len(), 200);
        assert_eq!(db.supplier.len(), 10);
        assert_eq!(db.partsupp.len(), 800);
        assert_eq!(db.nation.len(), 25);
        assert_eq!(db.region.len(), 5);
        // 1-7 lines per order, mean ≈ 4.
        let ratio = db.lineitem.len() as f64 / db.orders.len() as f64;
        assert!(ratio > 3.5 && ratio < 4.5, "lines/order = {ratio}");
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.lineitem.len(), b.lineitem.len());
        assert_eq!(
            a.lineitem.col("l_extendedprice").as_f64()[..50],
            b.lineitem.col("l_extendedprice").as_f64()[..50]
        );
        assert_eq!(
            a.orders.col("o_orderdate").as_i32()[..50],
            b.orders.col("o_orderdate").as_i32()[..50]
        );
    }

    #[test]
    fn different_seeds_differ() {
        let a = TpchDb::generate(TpchConfig::new(0.001, 1));
        let b = TpchDb::generate(TpchConfig::new(0.001, 2));
        assert_ne!(
            a.lineitem.col("l_quantity").as_f64()[..20],
            b.lineitem.col("l_quantity").as_f64()[..20]
        );
    }

    #[test]
    fn value_domains() {
        let db = small();
        let li = &db.lineitem;
        for &q in li.col("l_quantity").as_f64() {
            assert!((1.0..=50.0).contains(&q));
        }
        for &d in li.col("l_discount").as_f64() {
            assert!((0.0..=0.10).contains(&d));
        }
        for &t in li.col("l_tax").as_f64() {
            assert!((0.0..=0.08).contains(&t));
        }
        for &f in li.col("l_returnflag").as_u8() {
            assert!(f == b'R' || f == b'A' || f == b'N');
        }
        for &s in li.col("l_linestatus").as_u8() {
            assert!(s == b'O' || s == b'F');
        }
    }

    #[test]
    fn date_consistency() {
        let db = small();
        let li = &db.lineitem;
        let ship = li.col("l_shipdate").as_i32();
        let receipt = li.col("l_receiptdate").as_i32();
        let ok = li.col("l_orderkey").as_i64();
        let odate = db.orders.col("o_orderdate").as_i32();
        for i in 0..li.len() {
            assert!(receipt[i] > ship[i]);
            let o = (ok[i] - 1) as usize;
            assert!(ship[i] > odate[o]);
            assert!(ship[i] <= odate[o] + 121);
        }
    }

    #[test]
    fn orderdates_within_range() {
        let db = small();
        let (start, end) = (Dates::start(), Dates::end_orders());
        for &d in db.orders.col("o_orderdate").as_i32() {
            assert!(d >= start && d <= end);
        }
    }

    #[test]
    fn foreign_keys_resolve() {
        let db = small();
        let n_parts = db.part.len() as i64;
        let n_sups = db.supplier.len() as i64;
        let n_orders = db.orders.len() as i64;
        let n_cust = db.customer.len() as i64;
        for &pk in db.lineitem.col("l_partkey").as_i64() {
            assert!(pk >= 1 && pk <= n_parts);
        }
        for &sk in db.lineitem.col("l_suppkey").as_i64() {
            assert!(sk >= 1 && sk <= n_sups);
        }
        for &ok in db.lineitem.col("l_orderkey").as_i64() {
            assert!(ok >= 1 && ok <= n_orders);
        }
        for &ck in db.orders.col("o_custkey").as_i64() {
            assert!(ck >= 1 && ck <= n_cust);
        }
        for &nk in db.customer.col("c_nationkey").as_i32() {
            assert!((0..25).contains(&nk));
        }
    }

    #[test]
    fn returnflag_respects_current_date() {
        let db = small();
        let li = &db.lineitem;
        let receipt = li.col("l_receiptdate").as_i32();
        let flag = li.col("l_returnflag").as_u8();
        let cur = Dates::current();
        for i in 0..li.len() {
            if receipt[i] <= cur {
                assert!(flag[i] == b'R' || flag[i] == b'A');
            } else {
                assert_eq!(flag[i], b'N');
            }
        }
    }

    #[test]
    fn sf1_scale_bytes_near_1gb() {
        // Don't generate SF 1 in tests; check the arithmetic at SF 0.01.
        let db = TpchDb::generate(TpchConfig::new(0.01, 7));
        let bytes = db.bytes();
        // SF 0.01 ≈ 10 MB raw (ours is leaner than dbgen's ~10.5 MB with
        // comments/strings omitted; accept a broad band).
        assert!(bytes > 3_000_000 && bytes < 20_000_000, "bytes={bytes}");
    }

    #[test]
    fn partsupp_pairs_unique() {
        let db = small();
        let pk = db.partsupp.col("ps_partkey").as_i64();
        let sk = db.partsupp.col("ps_suppkey").as_i64();
        let mut seen = std::collections::HashSet::new();
        for i in 0..db.partsupp.len() {
            assert!(seen.insert((pk[i], sk[i])), "dup pair ({}, {})", pk[i], sk[i]);
        }
    }
}

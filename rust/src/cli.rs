//! Command-line argument parsing (clap is unavailable offline).
//!
//! Supports the subset the `lovelock` binary and examples need:
//! subcommands, `--flag`, `--key value`, `--key=value`, repeatable
//! options (`--param a=1 --param b=2`), positional arguments, typed
//! accessors with defaults, and generated `--help` text.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Declarative specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    /// Repeatable: every occurrence is collected (see [`Args::get_all`]).
    pub is_multi: bool,
}

/// A parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    multi: BTreeMap<String, Vec<String>>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.values.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.values
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get_u64(key, default as u64) as usize
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.values
            .get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }

    /// Every occurrence of a repeatable option, in command-line order.
    pub fn get_all(&self, key: &str) -> Vec<String> {
        self.multi.get(key).cloned().unwrap_or_default()
    }
}

/// A command parser: knows its options and its subcommands.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    opts: Vec<OptSpec>,
    subs: Vec<(&'static str, &'static str)>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new(), subs: Vec::new() }
    }

    /// Register a `--key value` option.
    pub fn opt(
        mut self,
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        self.opts.push(OptSpec { name, help, default, is_flag: false, is_multi: false });
        self
    }

    /// Register a boolean `--flag`.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true, is_multi: false });
        self
    }

    /// Register a repeatable `--key value` option: every occurrence is
    /// collected in order (`Args::get_all`).
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false, is_multi: true });
        self
    }

    /// Register a subcommand (for help text; parsing takes the first
    /// non-option token as the subcommand when any are registered).
    pub fn sub(mut self, name: &'static str, about: &'static str) -> Self {
        self.subs.push((name, about));
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}", self.name, self.about);
        let _ = writeln!(s, "\nUSAGE: {} [subcommand] [options]", self.name);
        if !self.subs.is_empty() {
            let _ = writeln!(s, "\nSUBCOMMANDS:");
            for (n, a) in &self.subs {
                let _ = writeln!(s, "  {n:<16} {a}");
            }
        }
        if !self.opts.is_empty() {
            let _ = writeln!(s, "\nOPTIONS:");
            for o in &self.opts {
                let d = o
                    .default
                    .map(|d| format!(" [default: {d}]"))
                    .unwrap_or_default();
                let kind = if o.is_flag { "" } else { " <value>" };
                let _ = writeln!(s, "  --{}{kind:<10} {}{d}", o.name, o.help);
            }
        }
        s
    }

    /// Parse a token stream (typically `std::env::args().skip(1)`).
    ///
    /// Returns `Err` with a message (including full help for `--help`).
    pub fn parse<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let toks: Vec<String> = argv.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if t == "--help" || t == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = t.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.help_text()))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("--{key} is a flag and takes no value"));
                    }
                    args.flags.insert(key, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            toks.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} expects a value"))?
                        }
                    };
                    if spec.is_multi {
                        args.multi.entry(key).or_default().push(val);
                    } else {
                        args.values.insert(key, val);
                    }
                }
            } else if args.subcommand.is_none() && !self.subs.is_empty() {
                if !self.subs.iter().any(|(n, _)| n == t) {
                    return Err(format!("unknown subcommand {t:?}\n\n{}", self.help_text()));
                }
                args.subcommand = Some(t.clone());
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("lovelock", "test")
            .sub("cost", "cost model")
            .sub("tpch", "run tpch")
            .opt("phi", Some("1"), "NIC multiplier")
            .opt("seed", Some("42"), "rng seed")
            .opt("name", None, "a name")
            .multi("param", "key=value override")
            .flag("verbose", "chatty")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = cmd().parse(s(&["cost", "--phi", "3", "--verbose"])).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("cost"));
        assert_eq!(a.get_u64("phi", 0), 3);
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_u64("seed", 0), 42); // default
    }

    #[test]
    fn parses_equals_form() {
        let a = cmd().parse(s(&["tpch", "--phi=2", "--name=abc"])).unwrap();
        assert_eq!(a.get_u64("phi", 0), 2);
        assert_eq!(a.get("name"), Some("abc"));
    }

    #[test]
    fn rejects_unknown_option() {
        assert!(cmd().parse(s(&["cost", "--bogus", "1"])).is_err());
    }

    #[test]
    fn rejects_unknown_subcommand() {
        assert!(cmd().parse(s(&["frobnicate"])).is_err());
    }

    #[test]
    fn help_is_error_with_text() {
        let err = cmd().parse(s(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
        assert!(err.contains("--phi"));
        assert!(err.contains("cost model"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = cmd().parse(s(&["tpch", "q1", "q6"])).unwrap();
        assert_eq!(a.positional, vec!["q1", "q6"]);
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(s(&["cost", "--phi"])).is_err());
    }

    #[test]
    fn flag_with_value_errors() {
        assert!(cmd().parse(s(&["cost", "--verbose=1"])).is_err());
    }

    #[test]
    fn multi_option_collects_in_order() {
        let a = cmd()
            .parse(s(&["tpch", "--param", "a=1", "--seed", "7", "--param=b=2"]))
            .unwrap();
        assert_eq!(a.get_all("param"), vec!["a=1", "b=2"]);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.get_all("nothing").is_empty());
        // A repeatable option still requires a value.
        assert!(cmd().parse(s(&["tpch", "--param"])).is_err());
    }

    #[test]
    fn typed_accessors() {
        let a = cmd().parse(s(&["cost", "--phi", "2"])).unwrap();
        assert_eq!(a.get_f64("phi", 0.0), 2.0);
        assert_eq!(a.get_usize("phi", 0), 2);
        assert_eq!(a.get_str("name", "dflt"), "dflt");
    }
}

//! Crate-wide error type — a minimal, dependency-free stand-in for
//! `anyhow` (the offline registry is empty, so the crate vendors nothing).
//!
//! [`Error`] is a message plus an optional chain of context strings, built
//! with the [`crate::err!`], [`crate::bail!`], and [`crate::ensure!`]
//! macros and the [`Context`] extension trait:
//!
//! ```
//! use lovelock::error::{Context, Result};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     s.parse::<u32>().context("not an integer")
//! }
//! assert!(parse("17").is_ok());
//! let err = parse("x").unwrap_err();
//! assert!(err.to_string().contains("not an integer"));
//! ```

use std::fmt;

/// A message-carrying error with optional context frames (outermost last).
#[derive(Debug)]
pub struct Error {
    msg: String,
    context: Vec<String>,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Self { msg: m.to_string(), context: Vec::new() }
    }

    /// Attach a context frame (shown before the root message).
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.context.push(c.to_string());
        self
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for c in self.context.iter().rev() {
            write!(f, "{c}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Self::msg(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Self::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Self::msg(e)
    }
}

impl From<std::array::TryFromSliceError> for Error {
    fn from(e: std::array::TryFromSliceError) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Self::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Self::msg(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`, mirroring the `anyhow` API surface the crate
/// uses.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context(self, msg: impl fmt::Display) -> Result<T>;

    /// Wrap the error (or `None`) with a lazily built context message.
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context(self, msg: impl fmt::Display) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`](crate::error::Error) built from a
/// format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_chains_context() {
        let e = Error::msg("root").context("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner: root");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            crate::ensure!(x >= 0, "negative: {x}");
            if x == 0 {
                crate::bail!("zero");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero");
        assert_eq!(f(-2).unwrap_err().to_string(), "negative: -2");
        let e = crate::err!("v={}", 9);
        assert_eq!(e.to_string(), "v=9");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), String> = Err("boom".into());
        assert_eq!(r.context("stage").unwrap_err().to_string(), "stage: boom");
        let o: Option<u32> = None;
        assert_eq!(o.with_context(|| "missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5).context("x").unwrap(), 5);
    }

    #[test]
    fn from_conversions() {
        fn io() -> Result<()> {
            std::fs::read("/definitely/not/a/path")?;
            Ok(())
        }
        assert!(io().is_err());
        let e: Error = "plain".into();
        assert_eq!(e.to_string(), "plain");
    }
}

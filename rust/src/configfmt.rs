//! Configuration formats: a TOML-subset parser and a JSON emitter.
//!
//! Lovelock's launcher reads cluster/experiment configs from `.toml` files
//! (sections, key = value, strings, numbers, booleans, arrays) and the
//! examples emit machine-readable run records as JSON. serde is not in the
//! offline registry, so both are implemented here; the TOML subset is
//! exactly what our configs use and the parser rejects what it does not
//! understand rather than misreading it.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed config: `section.key -> Value` (top-level keys have no dot).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

impl Config {
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn get_i64(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|s| s.as_str())
    }

    pub fn insert(&mut self, key: &str, v: Value) {
        self.entries.insert(key.to_string(), v);
    }
}

/// Parse a TOML-subset document.
pub fn parse_toml(text: &str) -> Result<Config, String> {
    let mut cfg = Config::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| format!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                return Err(format!("line {}: empty section name", lineno + 1));
            }
            section = name.to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = k.trim();
        if key.is_empty() {
            return Err(format!("line {}: empty key", lineno + 1));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let value = parse_value(v.trim())
            .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        cfg.entries.insert(full_key, value);
    }
    Ok(cfg)
}

fn strip_comment(line: &str) -> &str {
    // A `#` outside of a string starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s:?}"))?;
        return Ok(Value::Str(unescape(inner)?));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array: {s:?}"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = split_top_level(inner)?
            .into_iter()
            .map(|part| parse_value(part.trim()))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    // Numbers: prefer int when there is no '.', 'e', or 'E'.
    let numeric = s.replace('_', "");
    if numeric.contains('.') || numeric.contains('e') || numeric.contains('E') {
        numeric
            .parse::<f64>()
            .map(Value::Float)
            .map_err(|_| format!("bad number: {s:?}"))
    } else {
        numeric
            .parse::<i64>()
            .map(Value::Int)
            .map_err(|_| format!("bad value: {s:?}"))
    }
}

fn split_top_level(s: &str) -> Result<Vec<&str>, String> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => {
                depth = depth.checked_sub(1).ok_or("unbalanced brackets")?;
            }
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if in_str {
        return Err("unterminated string in array".into());
    }
    parts.push(&s[start..]);
    Ok(parts)
}

fn unescape(s: &str) -> Result<String, String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('"') => out.push('"'),
                Some('\\') => out.push('\\'),
                other => return Err(format!("bad escape: \\{other:?}")),
            }
        } else {
            out.push(c);
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------- JSON out

/// Minimal JSON document builder (objects, arrays, scalars) for run records.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    pub fn field(mut self, key: &str, v: impl Into<Json>) -> Self {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), v.into()));
        } else {
            panic!("field() on non-object Json");
        }
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if n.fract() == 0.0 && n.abs() < 1e15 {
                        let _ = write!(out, "{}", *n as i64);
                    } else {
                        let _ = write!(out, "{n}");
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let cfg = parse_toml(
            r#"
            # cluster config
            name = "lovelock-demo"
            [cluster]
            phi = 3
            slowdown = 1.2      # mu
            smartnic = true
            nodes = [4, 8, 16]
            labels = ["a", "b"]
            "#,
        )
        .unwrap();
        assert_eq!(cfg.get_str("name", ""), "lovelock-demo");
        assert_eq!(cfg.get_i64("cluster.phi", 0), 3);
        assert!((cfg.get_f64("cluster.slowdown", 0.0) - 1.2).abs() < 1e-12);
        assert!(cfg.get_bool("cluster.smartnic", false));
        let nodes = cfg.get("cluster.nodes").unwrap().as_array().unwrap();
        assert_eq!(nodes.len(), 3);
        assert_eq!(nodes[2].as_i64(), Some(16));
        let labels = cfg.get("cluster.labels").unwrap().as_array().unwrap();
        assert_eq!(labels[1].as_str(), Some("b"));
    }

    #[test]
    fn int_with_underscores() {
        let cfg = parse_toml("x = 1_000_000").unwrap();
        assert_eq!(cfg.get_i64("x", 0), 1_000_000);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let cfg = parse_toml(r##"s = "a#b" # real comment"##).unwrap();
        assert_eq!(cfg.get_str("s", ""), "a#b");
    }

    #[test]
    fn escapes_in_strings() {
        let cfg = parse_toml(r#"s = "a\nb\t\"c\"""#).unwrap();
        assert_eq!(cfg.get_str("s", ""), "a\nb\t\"c\"");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_toml("not a kv line").is_err());
        assert!(parse_toml("[unterminated").is_err());
        assert!(parse_toml("x = ").is_err());
        assert!(parse_toml("x = \"open").is_err());
        assert!(parse_toml("x = [1, 2").is_err());
        assert!(parse_toml("[]").is_err());
    }

    #[test]
    fn nested_arrays() {
        let cfg = parse_toml("m = [[1, 2], [3, 4]]").unwrap();
        let m = cfg.get("m").unwrap().as_array().unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[1].as_array().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn defaults_apply() {
        let cfg = parse_toml("").unwrap();
        assert_eq!(cfg.get_i64("missing", 7), 7);
        assert_eq!(cfg.get_str("missing", "d"), "d");
    }

    #[test]
    fn json_round_structure() {
        let j = Json::obj()
            .field("phi", 3u64)
            .field("mu", 1.22)
            .field("name", "fig4")
            .field("ok", true)
            .field("xs", Json::Arr(vec![Json::Num(1.0), Json::Num(2.5)]));
        let s = j.render();
        assert_eq!(
            s,
            r#"{"phi":3,"mu":1.22,"name":"fig4","ok":true,"xs":[1,2.5]}"#
        );
    }

    #[test]
    fn json_escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).render();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }
}

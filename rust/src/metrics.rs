//! Lightweight metrics registry: counters, gauges, and histograms.
//!
//! The coordinator, simulators, and the E2E drivers record into a
//! [`Registry`]; benches and examples render a snapshot at the end of a
//! run. Histograms use fixed log-spaced buckets, good enough for latency
//! distributions spanning ns..s.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge (bits of an f64).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

const HIST_BUCKETS: usize = 64;

/// Log-spaced histogram over positive values.
///
/// Bucket `i` covers `[2^(i/2), 2^((i+1)/2))` (half-powers of two), giving
/// ~19 decades of range with <50% relative error per bucket — fine for the
/// "how did tail latency move" questions the paper cares about.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_bits: Mutex<f64>,
    max_bits: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_bits: Mutex::new(0.0),
            max_bits: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    fn bucket_of(v: f64) -> usize {
        if v <= 1.0 {
            return 0;
        }
        let b = (2.0 * v.log2()).floor() as i64;
        b.clamp(0, HIST_BUCKETS as i64 - 1) as usize
    }

    /// Upper edge of bucket `i`.
    fn bucket_hi(i: usize) -> f64 {
        2f64.powf((i as f64 + 1.0) / 2.0)
    }

    pub fn observe(&self, v: f64) {
        let v = v.max(0.0);
        self.buckets[Self::bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        *self.sum_bits.lock().unwrap() += v;
        // max via CAS on bits (values are non-negative so bit order = value order)
        let bits = v.to_bits();
        let mut cur = self.max_bits.load(Ordering::Relaxed);
        while bits > cur {
            match self
                .max_bits
                .compare_exchange_weak(cur, bits, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(c) => cur = c,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            *self.sum_bits.lock().unwrap() / c as f64
        }
    }

    pub fn max(&self) -> f64 {
        f64::from_bits(self.max_bits.load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket upper edges.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target.max(1) {
                return Self::bucket_hi(i);
            }
        }
        Self::bucket_hi(HIST_BUCKETS - 1)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Named metric registry, cheaply cloneable handles.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::default())))
        {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("metric {name} already registered with a different type"),
        }
    }

    /// Render all metrics as aligned text lines.
    pub fn render(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    let _ = writeln!(out, "{name} = {}", c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(out, "{name} = {:.4}", g.get());
                }
                Metric::Histogram(h) => {
                    let _ = writeln!(
                        out,
                        "{name}: n={} mean={:.2} p50={:.2} p99={:.2} max={:.2}",
                        h.count(),
                        h.mean(),
                        h.quantile(0.50),
                        h.quantile(0.99),
                        h.max()
                    );
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge() {
        let r = Registry::new();
        let c = r.counter("requests");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = r.gauge("util");
        g.set(0.75);
        assert!((g.get() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn same_name_returns_same_metric() {
        let r = Registry::new();
        r.counter("x").add(2);
        assert_eq!(r.counter("x").get(), 2);
    }

    #[test]
    #[should_panic]
    fn type_confusion_panics() {
        let r = Registry::new();
        r.counter("x");
        r.gauge("x");
    }

    #[test]
    fn histogram_quantiles_ordered() {
        let h = Histogram::default();
        for i in 1..=1000 {
            h.observe(i as f64);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 <= p99);
        // log-bucketed: p50 within a bucket (~41%) of true 500
        assert!(p50 > 300.0 && p50 < 800.0, "p50={p50}");
        assert!((h.mean() - 500.5).abs() < 1.0);
        assert_eq!(h.max(), 1000.0);
    }

    #[test]
    fn histogram_concurrent_observe() {
        let h = Arc::new(Histogram::default());
        let threads: Vec<_> = (0..4)
            .map(|_| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..10_000 {
                        h.observe((i % 100) as f64);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 40_000);
    }

    #[test]
    fn render_contains_all() {
        let r = Registry::new();
        r.counter("a").inc();
        r.gauge("b").set(1.0);
        r.histogram("c").observe(10.0);
        let s = r.render();
        assert!(s.contains("a = 1"));
        assert!(s.contains("b = 1.0000"));
        assert!(s.contains("c: n=1"));
    }
}

//! Structural layer of the invariant checker: brace matching, function
//! extraction with `impl` qualification, the guard-liveness walk that
//! turns `.lock()` calls into held-across facts, and call-site
//! resolution shared by every reachability-based rule.
//!
//! ## Call-resolution policy
//!
//! Only three call shapes resolve to a callee, on purpose:
//!
//! 1. `self.foo(..)` — same `impl` block first, then same file, then a
//!    scope-wide unique name;
//! 2. `Type::foo(..)` / `module::foo(..)` — matching `impl` type first,
//!    then a unique free function;
//! 3. bare `foo(..)` — same file first, then scope-wide unique.
//!
//! A method on any *other* receiver (`guard.complete(..)`,
//! `self.field.pump(..)`) is never resolved. That is the structural
//! guarantee that keeps the lock graph free of false cycles: receiver
//! types are unknown to a tokenizer, and one wrong guess (`cv.wait`
//! resolving into a scheduler method, say) would fabricate an edge.
//! The cost is false negatives on dynamic call paths, which the rule
//! docs list explicitly.

use super::lex::{Comment, Tok, Token};
use std::collections::HashMap;

/// A lexed source file plus its bracket-match tables.
pub struct SourceFile {
    pub path: String,
    pub toks: Vec<Token>,
    pub comments: Vec<Comment>,
    /// `fwd[open] = close` for `{ ( [`; `usize::MAX` when unmatched.
    pub fwd: Vec<usize>,
    /// `rev[close] = open`; `usize::MAX` when unmatched.
    pub rev: Vec<usize>,
}

impl SourceFile {
    pub fn new(path: String, src: &str) -> Self {
        let (toks, comments) = super::lex::lex(src);
        let (fwd, rev) = match_table(&toks);
        SourceFile { path, toks, comments, fwd, rev }
    }

    pub fn ident(&self, i: usize) -> Option<&str> {
        match self.toks.get(i).map(|t| &t.tok) {
            Some(Tok::Ident(s)) => Some(s),
            _ => None,
        }
    }

    pub fn punct(&self, i: usize) -> Option<char> {
        match self.toks.get(i).map(|t| &t.tok) {
            Some(Tok::Punct(c)) => Some(*c),
            _ => None,
        }
    }

    pub fn line(&self, i: usize) -> u32 {
        self.toks.get(i).map(|t| t.line).unwrap_or(0)
    }
}

/// Pair up `{}`, `()`, `[]`. Mismatches are tolerated (pop whatever is
/// on top) so one stray token cannot wedge the whole file.
fn match_table(toks: &[Token]) -> (Vec<usize>, Vec<usize>) {
    let mut fwd = vec![usize::MAX; toks.len()];
    let mut rev = vec![usize::MAX; toks.len()];
    let mut stack: Vec<usize> = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        match t.tok {
            Tok::Punct('{') | Tok::Punct('(') | Tok::Punct('[') => stack.push(i),
            Tok::Punct('}') | Tok::Punct(')') | Tok::Punct(']') => {
                if let Some(o) = stack.pop() {
                    fwd[o] = i;
                    rev[i] = o;
                }
            }
            _ => {}
        }
    }
    (fwd, rev)
}

/// One `.lock()` acquisition site.
#[derive(Debug, Clone)]
pub struct Acq {
    /// Receiver field name (`queries` in `self.queries.lock()`).
    pub lock: String,
    pub line: u32,
}

/// A lock acquired while another was live, within one function body.
#[derive(Debug, Clone)]
pub struct Edge {
    pub held: String,
    pub lock: String,
    pub line: u32,
}

#[derive(Debug, Clone, PartialEq)]
pub enum CallKind {
    /// `self.name(..)`.
    SelfMethod,
    /// `Qual::name(..)` — `Qual` is a type or module segment.
    Typed(String),
    /// `name(..)`.
    Bare,
}

/// A resolvable call site with the locks live at the moment of the call.
#[derive(Debug, Clone)]
pub struct Call {
    pub kind: CallKind,
    pub name: String,
    pub line: u32,
    pub held: Vec<String>,
}

/// An extracted function with its per-body lock/call facts.
#[derive(Debug)]
pub struct FnInfo {
    pub file: usize,
    pub name: String,
    /// `Some("WorkerShared")` for `impl WorkerShared { fn … }`.
    pub impl_ty: Option<String>,
    pub line: u32,
    /// Token indices of the body's `{` and `}`.
    pub body: (usize, usize),
    pub is_test: bool,
    pub acqs: Vec<Acq>,
    pub edges: Vec<Edge>,
    pub calls: Vec<Call>,
}

impl FnInfo {
    /// `File::name` style label for diagnostics.
    pub fn qual(&self) -> String {
        match &self.impl_ty {
            Some(t) => format!("{t}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// Extraction result across a file set.
pub struct Extracted {
    pub fns: Vec<FnInfo>,
}

const ITEM_KEYWORDS: &[&str] =
    &["fn", "mod", "struct", "enum", "trait", "impl", "const", "static", "use", "type"];

pub fn extract(files: &[SourceFile]) -> Extracted {
    let mut fns = Vec::new();
    for (fi, f) in files.iter().enumerate() {
        extract_file(fi, f, &mut fns);
    }
    Extracted { fns }
}

fn extract_file(fi: usize, f: &SourceFile, out: &mut Vec<FnInfo>) {
    let toks = &f.toks;
    let mut impl_stack: Vec<(Option<String>, usize)> = Vec::new();
    let mut test_regions: Vec<(usize, usize)> = Vec::new();
    let mut pending_test = false;
    let mut i = 0;
    while i < toks.len() {
        while impl_stack.last().is_some_and(|(_, close)| *close <= i) {
            impl_stack.pop();
        }
        match &toks[i].tok {
            Tok::Punct('#') => {
                // Attribute: `#[…]` or `#![…]`.
                let open = if f.punct(i + 1) == Some('[') {
                    i + 1
                } else if f.punct(i + 1) == Some('!') && f.punct(i + 2) == Some('[') {
                    i + 2
                } else {
                    i += 1;
                    continue;
                };
                let close = f.fwd[open];
                if close != usize::MAX {
                    let has_test = toks[open + 1..close]
                        .iter()
                        .any(|t| matches!(&t.tok, Tok::Ident(s) if s == "test"));
                    if has_test {
                        pending_test = true;
                    }
                    i = close + 1;
                } else {
                    i += 1;
                }
            }
            Tok::Ident(kw) if kw == "impl" => {
                if let Some((ty, body_open)) = parse_impl_header(f, i) {
                    let close = f.fwd[body_open];
                    if close != usize::MAX {
                        impl_stack.push((ty, close));
                    }
                    pending_test = false;
                    i = body_open + 1;
                } else {
                    i += 1;
                }
            }
            Tok::Ident(kw) if kw == "mod" => {
                // `mod name { … }` or `mod name;`. Bodies are scanned
                // (fns inside a #[cfg(test)] mod get is_test via the
                // recorded region).
                if pending_test {
                    let mut j = i + 1;
                    while j < toks.len()
                        && f.punct(j) != Some('{')
                        && f.punct(j) != Some(';')
                    {
                        j += 1;
                    }
                    if f.punct(j) == Some('{') && f.fwd[j] != usize::MAX {
                        test_regions.push((j, f.fwd[j]));
                    }
                }
                pending_test = false;
                i += 1;
            }
            Tok::Ident(kw) if kw == "fn" => {
                if let Some((name, body_open)) = parse_fn_header(f, i) {
                    let body_close = f.fwd[body_open];
                    if body_close != usize::MAX {
                        let in_region = test_regions
                            .iter()
                            .any(|(o, c)| body_open > *o && body_open < *c);
                        let impl_ty = impl_stack.last().and_then(|(t, _)| t.clone());
                        let mut info = FnInfo {
                            file: fi,
                            name,
                            impl_ty,
                            line: toks[i].line,
                            body: (body_open, body_close),
                            is_test: pending_test || in_region,
                            acqs: Vec::new(),
                            edges: Vec::new(),
                            calls: Vec::new(),
                        };
                        analyze_body(f, &mut info);
                        out.push(info);
                        pending_test = false;
                        // Skip the body: nested fns are not items we
                        // track, and skipping keeps `impl Trait` in
                        // expression position out of the item scan.
                        i = body_close + 1;
                        continue;
                    }
                }
                pending_test = false;
                i += 1;
            }
            Tok::Ident(kw) if ITEM_KEYWORDS.contains(&kw.as_str()) => {
                pending_test = false;
                i += 1;
            }
            _ => i += 1,
        }
    }
}

/// Parse `impl<…> Type` / `impl Trait for Type`, returning the Self
/// type name and the body-brace index.
fn parse_impl_header(f: &SourceFile, i: usize) -> Option<(Option<String>, usize)> {
    let toks = &f.toks;
    let mut j = i + 1;
    let mut angle = 0i32;
    let mut ty: Option<String> = None;
    let mut after_where = false;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('<') if f.punct(j - 1) != Some('-') => angle += 1,
            Tok::Punct('>') if f.punct(j - 1) != Some('-') => angle -= 1,
            Tok::Punct('{') if angle <= 0 => return Some((ty, j)),
            Tok::Punct(';') if angle <= 0 => return None, // `impl Foo;` — malformed
            Tok::Punct('(') | Tok::Punct('[') if f.fwd[j] != usize::MAX => {
                j = f.fwd[j];
            }
            Tok::Ident(s) if angle <= 0 && !after_where => {
                if s == "for" {
                    ty = None; // the Self type follows `for`
                } else if s == "where" {
                    after_where = true;
                } else {
                    ty = Some(s.clone()); // last path segment wins
                }
            }
            _ => {}
        }
        j += 1;
    }
    None
}

/// Parse `fn name<…>(…) -> … {`, returning the name and body `{` index.
fn parse_fn_header(f: &SourceFile, i: usize) -> Option<(String, usize)> {
    let name = f.ident(i + 1)?.to_string();
    let toks = &f.toks;
    let mut j = i + 2;
    let mut angle = 0i32;
    while j < toks.len() {
        match &toks[j].tok {
            Tok::Punct('<') if f.punct(j - 1) != Some('-') => angle += 1,
            Tok::Punct('>') if f.punct(j - 1) != Some('-') => angle -= 1,
            Tok::Punct('(') | Tok::Punct('[') if f.fwd[j] != usize::MAX => {
                j = f.fwd[j];
            }
            Tok::Punct('{') => {
                if angle <= 0 {
                    return Some((name, j));
                }
                j = f.fwd[j].min(toks.len());
            }
            Tok::Punct(';') if angle <= 0 => return None, // declaration only
            _ => {}
        }
        j += 1;
    }
    None
}

/// How long a guard produced at token `i` stays live.
enum GuardLife {
    /// Dies at this token index (a `;`, or a construct's closing `}`).
    At(usize),
    /// Named guard: dies at the enclosing scope's `}` unless dropped.
    Named(String, usize),
}

struct Live {
    lock: String,
    name: Option<String>,
    dies: usize,
}

const CALL_KEYWORDS: &[&str] = &[
    "if", "while", "match", "for", "loop", "return", "in", "as", "move", "else", "unsafe",
    "ref", "mut", "let", "fn", "break", "continue", "where",
];

/// Single linear pass over a function body: track live lock guards,
/// emit held-while-acquiring edges and call sites with the held set.
fn analyze_body(f: &SourceFile, info: &mut FnInfo) {
    let (open, close) = info.body;
    let toks = &f.toks;
    let mut scopes: Vec<usize> = vec![close];
    let mut live: Vec<Live> = Vec::new();
    let mut i = open + 1;
    while i < close {
        live.retain(|g| g.dies > i);
        match &toks[i].tok {
            Tok::Punct('{') => {
                if f.fwd[i] != usize::MAX {
                    scopes.push(f.fwd[i]);
                }
            }
            Tok::Punct('}') => {
                if scopes.len() > 1 {
                    scopes.pop();
                }
            }
            Tok::Ident(id)
                if id == "drop"
                    && f.punct(i + 1) == Some('(')
                    && f.ident(i + 2).is_some()
                    && f.punct(i + 3) == Some(')') =>
            {
                let victim = f.ident(i + 2).unwrap().to_string();
                live.retain(|g| g.name.as_deref() != Some(victim.as_str()));
                i += 4;
                continue;
            }
            Tok::Ident(id)
                if id == "lock"
                    && f.punct(i.wrapping_sub(1)) == Some('.')
                    && f.punct(i + 1) == Some('(')
                    && f.punct(i + 2) == Some(')') =>
            {
                if let Some((lock, chain_start)) = lock_receiver(f, i) {
                    let line = toks[i].line;
                    for g in &live {
                        info.edges.push(Edge {
                            held: g.lock.clone(),
                            lock: lock.clone(),
                            line,
                        });
                    }
                    info.acqs.push(Acq { lock: lock.clone(), line });
                    let scope_close = *scopes.last().unwrap_or(&close);
                    let lifev = classify_guard(f, i, chain_start, scope_close, close);
                    let (name, dies) = match lifev {
                        GuardLife::At(d) => (None, d),
                        GuardLife::Named(n, d) => (Some(n), d),
                    };
                    live.push(Live { lock, name, dies });
                }
            }
            Tok::Ident(name) if f.punct(i + 1) == Some('(') => {
                if !CALL_KEYWORDS.contains(&name.as_str()) {
                    if let Some(kind) = call_kind(f, i) {
                        info.calls.push(Call {
                            kind,
                            name: name.clone(),
                            line: toks[i].line,
                            held: live.iter().map(|g| g.lock.clone()).collect(),
                        });
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
}

/// Extract the receiver field of `<chain>.lock()` at ident index `i`,
/// plus the chain's first token index. Returns `None` when the receiver
/// is not a plain ident (e.g. `fetch().lock()`).
fn lock_receiver(f: &SourceFile, i: usize) -> Option<(String, usize)> {
    let mut k = i.checked_sub(2)?;
    if f.punct(k) == Some(']') && f.rev[k] != usize::MAX {
        k = f.rev[k].checked_sub(1)?;
    }
    let recv = f.ident(k)?.to_string();
    if recv == "self" {
        return None; // a method literally named `lock` on self
    }
    // Walk the chain back over `seg.`-style prefixes to its first token.
    let mut start = k;
    while start >= 2 && f.punct(start - 1) == Some('.') && f.ident(start - 2).is_some() {
        start -= 2;
    }
    Some((recv, start))
}

/// Decide how long the guard from the `.lock()` at `i` lives.
fn classify_guard(
    f: &SourceFile,
    i: usize,
    chain_start: usize,
    scope_close: usize,
    body_close: usize,
) -> GuardLife {
    // Backward: what context does this acquisition sit in?
    #[derive(PartialEq)]
    enum Ctx {
        Stmt,
        Let,
        Construct,
    }
    let mut ctx = Ctx::Stmt;
    let mut b = chain_start.wrapping_sub(1);
    for _ in 0..40 {
        if b == usize::MAX || b == 0 {
            break;
        }
        match &f.toks[b].tok {
            Tok::Punct(';') | Tok::Punct('{') | Tok::Punct('}') | Tok::Punct(',')
            | Tok::Punct('(') => break,
            Tok::Punct(')') | Tok::Punct(']') if f.rev[b] != usize::MAX => {
                b = f.rev[b].wrapping_sub(1);
                continue;
            }
            Tok::Ident(s) if s == "let" => {
                // `if let` / `while let` scrutinees live for the construct.
                if matches!(f.ident(b.wrapping_sub(1)), Some("if" | "while")) {
                    ctx = Ctx::Construct;
                } else {
                    ctx = Ctx::Let;
                }
                break;
            }
            Tok::Ident(s) if matches!(s.as_str(), "if" | "while" | "match" | "for" | "else") => {
                ctx = Ctx::Construct;
                break;
            }
            Tok::Ident(s) if s == "return" => break,
            _ => {}
        }
        b = b.wrapping_sub(1);
    }

    match ctx {
        Ctx::Construct => GuardLife::At(construct_end(f, i, body_close)),
        Ctx::Let => {
            // Named guard only for the exact simple shape
            // `let [mut] NAME = <chain>.lock().unwrap();` (or
            // `.expect("…");`) — anything longer makes the guard a
            // statement temporary under Rust's drop rules.
            let name_idx = chain_start.wrapping_sub(2);
            let named = f.punct(chain_start.wrapping_sub(1)) == Some('=')
                && f.ident(name_idx).is_some();
            let simple = {
                let mut j = i + 3; // token after `lock ( )`
                if f.punct(j) == Some('.')
                    && matches!(f.ident(j + 1), Some("unwrap" | "expect"))
                    && f.punct(j + 2) == Some('(')
                    && f.fwd[j + 2] != usize::MAX
                {
                    j = f.fwd[j + 2] + 1;
                    f.punct(j) == Some(';')
                } else {
                    false
                }
            };
            if named && simple {
                GuardLife::Named(f.ident(name_idx).unwrap().to_string(), scope_close)
            } else {
                GuardLife::At(stmt_end(f, i, scope_close))
            }
        }
        Ctx::Stmt => GuardLife::At(stmt_end(f, i, scope_close)),
    }
}

/// Next `;` at this brace level, else the scope's close.
fn stmt_end(f: &SourceFile, i: usize, scope_close: usize) -> usize {
    let mut j = i + 1;
    while j < scope_close {
        match &f.toks[j].tok {
            Tok::Punct('{') | Tok::Punct('(') | Tok::Punct('[') if f.fwd[j] != usize::MAX => {
                j = f.fwd[j];
            }
            Tok::Punct(';') => return j,
            _ => {}
        }
        j += 1;
    }
    scope_close
}

/// Closing `}` of the construct whose header contains token `i`,
/// extended through any `else` chain.
fn construct_end(f: &SourceFile, i: usize, body_close: usize) -> usize {
    let mut j = i + 1;
    // First block at this level is the construct body.
    while j < body_close {
        match &f.toks[j].tok {
            Tok::Punct('(') | Tok::Punct('[') if f.fwd[j] != usize::MAX => j = f.fwd[j],
            Tok::Punct('{') if f.fwd[j] != usize::MAX => {
                let mut end = f.fwd[j];
                // `} else {` / `} else if … {` chains extend the life.
                while f.ident(end + 1) == Some("else") {
                    let mut k = end + 2;
                    let mut found = false;
                    while k < body_close {
                        match &f.toks[k].tok {
                            Tok::Punct('(') | Tok::Punct('[') if f.fwd[k] != usize::MAX => {
                                k = f.fwd[k]
                            }
                            Tok::Punct('{') if f.fwd[k] != usize::MAX => {
                                end = f.fwd[k];
                                found = true;
                                break;
                            }
                            _ => {}
                        }
                        k += 1;
                    }
                    if !found {
                        break;
                    }
                }
                return end;
            }
            Tok::Punct(';') => return j, // `for x in it.lock()…;` degenerate
            _ => {}
        }
        j += 1;
    }
    body_close
}

/// Classify a call at ident index `i` (which is followed by `(`).
/// Returns `None` for shapes the resolver refuses on principle.
fn call_kind(f: &SourceFile, i: usize) -> Option<CallKind> {
    if i == 0 {
        return Some(CallKind::Bare);
    }
    match f.punct(i - 1) {
        Some('.') => {
            // Method call: resolve only `self.name(…)`.
            if f.ident(i.wrapping_sub(2)) == Some("self")
                && f.punct(i.wrapping_sub(3)) != Some('.')
            {
                Some(CallKind::SelfMethod)
            } else {
                None
            }
        }
        Some(':') if f.punct(i.wrapping_sub(2)) == Some(':') => {
            // Path call `Qual::name(…)`; skip `<X as Y>::name`.
            f.ident(i.wrapping_sub(3)).map(|q| CallKind::Typed(q.to_string()))
        }
        Some('!') => None, // macro bang — not a call
        _ => Some(CallKind::Bare),
    }
}

/// Name-indexed resolver over an in-scope, non-test subset of fns.
pub struct Resolver<'a> {
    pub fns: &'a [FnInfo],
    by_name: HashMap<&'a str, Vec<usize>>,
}

impl<'a> Resolver<'a> {
    /// `in_scope[i]` gates which fns are resolution candidates.
    pub fn new(fns: &'a [FnInfo], in_scope: &[bool]) -> Self {
        let mut by_name: HashMap<&str, Vec<usize>> = HashMap::new();
        for (i, f) in fns.iter().enumerate() {
            if in_scope[i] && !f.is_test {
                by_name.entry(f.name.as_str()).or_default().push(i);
            }
        }
        Resolver { fns, by_name }
    }

    pub fn resolve(&self, caller: &FnInfo, call: &Call) -> Option<usize> {
        let cands = self.by_name.get(call.name.as_str())?;
        let unique = |v: Vec<usize>| if v.len() == 1 { Some(v[0]) } else { None };
        match &call.kind {
            CallKind::SelfMethod => {
                let ty = caller.impl_ty.as_deref();
                let same_impl: Vec<usize> = cands
                    .iter()
                    .copied()
                    .filter(|&c| {
                        self.fns[c].impl_ty.as_deref() == ty && self.fns[c].file == caller.file
                    })
                    .collect();
                if let Some(c) = unique(same_impl) {
                    return Some(c);
                }
                let same_ty: Vec<usize> = cands
                    .iter()
                    .copied()
                    .filter(|&c| self.fns[c].impl_ty.as_deref() == ty)
                    .collect();
                if let Some(c) = unique(same_ty) {
                    return Some(c);
                }
                let same_file: Vec<usize> =
                    cands.iter().copied().filter(|&c| self.fns[c].file == caller.file).collect();
                unique(same_file).or_else(|| unique(cands.clone()))
            }
            CallKind::Typed(q) => {
                let ty = if q == "Self" { caller.impl_ty.as_deref() } else { Some(q.as_str()) };
                let same_ty: Vec<usize> = cands
                    .iter()
                    .copied()
                    .filter(|&c| self.fns[c].impl_ty.as_deref() == ty)
                    .collect();
                if let Some(c) = unique(same_ty) {
                    return Some(c);
                }
                // Module-path call to a free fn (`planir::compile`).
                let free: Vec<usize> =
                    cands.iter().copied().filter(|&c| self.fns[c].impl_ty.is_none()).collect();
                unique(free).or_else(|| unique(cands.clone()))
            }
            CallKind::Bare => {
                let same_file: Vec<usize> =
                    cands.iter().copied().filter(|&c| self.fns[c].file == caller.file).collect();
                unique(same_file).or_else(|| unique(cands.clone()))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one_file(src: &str) -> (Vec<SourceFile>, Extracted) {
        let files = vec![SourceFile::new("rust/src/coordinator/t.rs".into(), src)];
        let ex = extract(&files);
        (files, ex)
    }

    #[test]
    fn extracts_impl_qualified_fns_and_skips_tests() {
        let src = r#"
            impl Leader {
                pub fn go(&self) {}
            }
            fn free() {}
            #[cfg(test)]
            mod tests {
                #[test]
                fn t() {}
                fn helper() {}
            }
        "#;
        let (_, ex) = one_file(src);
        let names: Vec<(String, bool)> =
            ex.fns.iter().map(|f| (f.qual(), f.is_test)).collect();
        assert!(names.contains(&("Leader::go".into(), false)));
        assert!(names.contains(&("free".into(), false)));
        assert!(names.contains(&("t".into(), true)));
        assert!(names.contains(&("helper".into(), true)));
    }

    #[test]
    fn named_guard_spans_scope_and_drop_kills_it() {
        let src = r#"
            impl S {
                fn a(&self) {
                    let mut q = self.queries.lock().unwrap();
                    let mut s = self.sched.lock().unwrap();
                    q.push(s.pop());
                }
                fn b(&self) {
                    let mut q = self.queries.lock().unwrap();
                    drop(q);
                    let mut s = self.sched.lock().unwrap();
                    s.clear();
                }
            }
        "#;
        let (_, ex) = one_file(src);
        let a = ex.fns.iter().find(|f| f.name == "a").unwrap();
        assert_eq!(a.edges.len(), 1);
        assert_eq!((a.edges[0].held.as_str(), a.edges[0].lock.as_str()), ("queries", "sched"));
        let b = ex.fns.iter().find(|f| f.name == "b").unwrap();
        assert!(b.edges.is_empty(), "drop() must release the guard: {:?}", b.edges);
    }

    #[test]
    fn statement_temp_dies_at_semicolon() {
        let src = r#"
            fn f(x: &X) {
                let n: Vec<u32> = x.stats.lock().unwrap().clone();
                let mut d = x.dead.lock().unwrap();
                d.extend(n);
            }
        "#;
        let (_, ex) = one_file(src);
        assert!(ex.fns[0].edges.is_empty(), "temp guard leaked: {:?}", ex.fns[0].edges);
        assert_eq!(ex.fns[0].acqs.len(), 2);
    }

    #[test]
    fn construct_scoped_temp_lives_for_the_construct() {
        let src = r#"
            fn f(x: &X) {
                if let Some(v) = x.heard.lock().unwrap().get(0) {
                    let d = x.dead.lock().unwrap();
                    use_it(v, d);
                }
                let q = x.queries.lock().unwrap();
                q.len();
            }
        "#;
        let (_, ex) = one_file(src);
        let edges: Vec<(String, String)> =
            ex.fns[0].edges.iter().map(|e| (e.held.clone(), e.lock.clone())).collect();
        assert_eq!(edges, vec![("heard".into(), "dead".into())]);
    }

    #[test]
    fn calls_record_held_locks_and_receiver_policy() {
        let src = r#"
            impl S {
                fn outer(&self) {
                    let g = self.queries.lock().unwrap();
                    self.inner();
                    other.never_resolved();
                    g.touch();
                }
                fn inner(&self) {}
            }
        "#;
        let (_, ex) = one_file(src);
        let outer = ex.fns.iter().find(|f| f.name == "outer").unwrap();
        let names: Vec<&str> = outer.calls.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, vec!["inner"], "non-self receivers must not be recorded");
        assert_eq!(outer.calls[0].held, vec!["queries".to_string()]);
    }

    #[test]
    fn match_scrutinee_guard_is_construct_scoped() {
        let src = r#"
            fn f(x: &X) {
                let db = match x.catalog.lock().unwrap().get(0) {
                    Some(d) => d,
                    None => return,
                };
                let p = x.plans.lock().unwrap();
                p.insert(db);
            }
        "#;
        let (_, ex) = one_file(src);
        assert!(
            ex.fns[0].edges.is_empty(),
            "match-scrutinee temp must die at match end: {:?}",
            ex.fns[0].edges
        );
    }
}

//! `lovelock lint` — a zero-dependency invariant checker over
//! `rust/src/**`, in the same hand-rolled spirit as the SQL front end:
//! a lightweight Rust tokenizer ([`lex`]), a brace-tree/function
//! extractor with a lock-guard liveness walk ([`fns`]), and four rules
//! grounded in invariants this repo has already broken once:
//!
//! | RULE-ID           | invariant                                           |
//! |-------------------|-----------------------------------------------------|
//! | `lock-order`      | coordinator lock graph acyclic + canonical order    |
//! | `hot-path-alloc`  | no fresh allocation reachable from morsel kernels   |
//! | `wire-tag`        | tag constants collision-free, matches total         |
//! | `no-panic-worker` | worker decode/compile paths return errors, not panics |
//! | `lint-allow`      | (meta) every allow comment carries a reason         |
//!
//! Diagnostics are `file:line: RULE-ID message` on stdout (or a JSON
//! array with `--json`). A finding is suppressed by an allow comment
//! **with a mandatory reason** on the same or preceding line:
//!
//! ```text
//! // lint: allow(no-panic-worker) wired once at startup, before any frame
//! ```
//!
//! Codec indexing is proven rather than allowed: a `// bound: …`
//! comment citing the length check satisfies `no-panic-worker`'s
//! indexing sub-check.

pub mod fns;
pub mod hot_path;
pub mod lex;
pub mod lock_order;
pub mod no_panic;
pub mod wire_tags;

use crate::Result;
use fns::SourceFile;
use std::collections::{BTreeMap, BTreeSet};

/// One diagnostic.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, w: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(w, "{}:{}: {} {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Per-file allowlist: `// lint: allow(RULE-ID) reason` and
/// `// bound: …` annotations. An annotation covers its own line and
/// the next line (so a comment above the flagged expression works).
#[derive(Default)]
pub struct Allows {
    /// line -> rules allowed there (with a non-empty reason).
    allows: BTreeMap<u32, BTreeSet<String>>,
    bounds: BTreeSet<u32>,
}

impl Allows {
    pub fn allowed(&self, rule: &str, line: u32) -> bool {
        [line, line.saturating_sub(1)]
            .iter()
            .any(|l| self.allows.get(l).is_some_and(|rs| rs.contains(rule)))
    }

    pub fn bound(&self, line: u32) -> bool {
        self.bounds.contains(&line) || self.bounds.contains(&line.saturating_sub(1))
    }
}

/// Parse a file's comments into its allowlist; missing reasons become
/// `lint-allow` diagnostics (the allow still suppresses, so a fix
/// doesn't cascade, but CI fails until the reason is written).
fn parse_allows(file: &SourceFile, diags: &mut Vec<Diag>) -> Allows {
    let mut a = Allows::default();
    for c in &file.comments {
        let text = c.text.trim();
        if let Some(rest) = text.strip_prefix("bound:") {
            if !rest.trim().is_empty() {
                a.bounds.insert(c.line);
            }
            continue;
        }
        let Some(at) = text.find("lint: allow(") else { continue };
        let rest = &text[at + "lint: allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let reason = rest[close + 1..].trim();
        if reason.is_empty() {
            diags.push(Diag {
                file: file.path.clone(),
                line: c.line,
                rule: "lint-allow",
                msg: format!(
                    "allow({rule}) has no reason — `// lint: allow(RULE-ID) why it is safe`"
                ),
            });
        }
        a.allows.entry(c.line).or_default().insert(rule);
    }
    a
}

/// Lint a set of `(path, source)` pairs. The testable core: fixtures
/// feed virtual paths here, the CLI feeds the real tree.
pub fn lint_sources(sources: &[(String, String)]) -> Vec<Diag> {
    let files: Vec<SourceFile> =
        sources.iter().map(|(p, s)| SourceFile::new(p.clone(), s)).collect();
    let mut diags = Vec::new();
    let allows: Vec<Allows> = files.iter().map(|f| parse_allows(f, &mut diags)).collect();
    let extracted = fns::extract(&files);
    lock_order::check(&files, &extracted, &allows, &mut diags);
    hot_path::check(&files, &extracted, &allows, &mut diags);
    wire_tags::check(&files, &allows, &mut diags);
    no_panic::check(&files, &extracted, &allows, &mut diags);
    diags.sort();
    diags.dedup();
    diags
}

/// Recursively collect `.rs` files under each path (or the path itself
/// for a plain file), sorted for deterministic output.
pub fn load_paths(paths: &[String]) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for p in paths {
        collect(std::path::Path::new(p), &mut out)?;
    }
    out.sort();
    Ok(out)
}

fn collect(p: &std::path::Path, out: &mut Vec<(String, String)>) -> Result<()> {
    if p.is_dir() {
        let mut entries: Vec<_> = std::fs::read_dir(p)
            .map_err(crate::error::Error::msg)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .collect();
        entries.sort();
        for e in entries {
            collect(&e, out)?;
        }
    } else if p.extension().is_some_and(|e| e == "rs") {
        let text = std::fs::read_to_string(p).map_err(crate::error::Error::msg)?;
        out.push((p.to_string_lossy().into_owned(), text));
    }
    Ok(())
}

/// Render diagnostics as a JSON array (machine-readable `--json`).
pub fn render_json(diags: &[Diag]) -> String {
    let mut s = String::from("[");
    for (i, d) in diags.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n  {{\"file\":\"{}\",\"line\":{},\"rule\":\"{}\",\"msg\":\"{}\"}}",
            json_escape(&d.file),
            d.line,
            d.rule,
            json_escape(&d.msg)
        ));
    }
    s.push_str(if diags.is_empty() { "]" } else { "\n]" });
    s
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lint_one(path: &str, src: &str) -> Vec<Diag> {
        lint_sources(&[(path.to_string(), src.to_string())])
    }

    #[test]
    fn allow_without_reason_is_flagged_but_still_suppresses() {
        let src = r#"
            impl WorkerShared {
                fn on_x(&self) -> u32 {
                    // lint: allow(no-panic-worker)
                    self.v.get().expect("wired")
                }
            }
        "#;
        let diags = lint_one("rust/src/coordinator/service.rs", src);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].rule, "lint-allow");
    }

    #[test]
    fn reasoned_allow_is_silent() {
        let src = r#"
            impl WorkerShared {
                fn on_x(&self) -> u32 {
                    // lint: allow(no-panic-worker) wired once at startup before any frame
                    self.v.get().expect("wired")
                }
            }
        "#;
        let diags = lint_one("rust/src/coordinator/service.rs", src);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn json_renders_and_escapes() {
        let diags = vec![Diag {
            file: "a\"b.rs".into(),
            line: 3,
            rule: "wire-tag",
            msg: "x\ny".into(),
        }];
        let j = render_json(&diags);
        assert!(j.contains("\"rule\":\"wire-tag\""));
        assert!(j.contains("a\\\"b.rs"));
        assert!(j.contains("x\\ny"));
        assert_eq!(render_json(&[]), "[]");
    }

    #[test]
    fn diag_display_format() {
        let d = Diag { file: "f.rs".into(), line: 7, rule: "lock-order", msg: "boom".into() };
        assert_eq!(d.to_string(), "f.rs:7: lock-order boom");
    }
}

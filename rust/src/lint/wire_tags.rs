//! RULE `wire-tag` — the protocol's method-tag constants and the tag
//! matches over wire bytes must stay collision-free and total.
//!
//! Three checks:
//!
//! 1. **Duplicate tags.** Two `METHOD_*` constants sharing a numeric
//!    value (a frame routed to the wrong handler), or one `match`
//!    containing two arms with the same literal value (the second is
//!    dead — rustc warns on overlapping literals but not through
//!    macro-generated arms).
//! 2. **Encode/decode symmetry.** Every `METHOD_*` constant declared
//!    in `protocol.rs` must appear both as a match-arm *pattern*
//!    (something decodes it) and in a non-pattern position
//!    (something sends or routes it), scanning `protocol.rs`,
//!    `service.rs`, and `rpc.rs` outside test code.
//! 3. **Rejecting defaults.** Any `match` in `protocol.rs`/`plan.rs`/
//!    `wirefmt.rs` with two or more integer-literal or `METHOD_*`
//!    arms is a tag dispatch and must end in a default arm (`_` or a
//!    lone binding) whose body rejects — contains `bail`/`Err`/`err`
//!    — so truncated or hostile bytes fail loudly instead of falling
//!    through.

use super::fns::SourceFile;
use super::lex::{num_value, Tok};
use super::{Allows, Diag};
use std::collections::BTreeMap;

pub const RULE: &str = "wire-tag";

/// Token ranges of `#[cfg(test)] mod … { … }` bodies in one file —
/// recomputed here because this rule scans files, not fns.
fn test_regions(f: &SourceFile) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut pending = false;
    let mut i = 0;
    while i < f.toks.len() {
        match &f.toks[i].tok {
            Tok::Punct('#') if f.punct(i + 1) == Some('[') => {
                let close = f.fwd[i + 1];
                if close != usize::MAX {
                    if f.toks[i + 2..close]
                        .iter()
                        .any(|t| matches!(&t.tok, Tok::Ident(s) if s == "test"))
                    {
                        pending = true;
                    }
                    i = close + 1;
                    continue;
                }
            }
            Tok::Ident(kw) if kw == "mod" && pending => {
                let mut j = i + 1;
                while j < f.toks.len() && f.punct(j) != Some('{') && f.punct(j) != Some(';') {
                    j += 1;
                }
                if f.punct(j) == Some('{') && f.fwd[j] != usize::MAX {
                    regions.push((j, f.fwd[j]));
                    i = f.fwd[j] + 1;
                    pending = false;
                    continue;
                }
                pending = false;
            }
            Tok::Ident(kw) if kw == "fn" && pending => {
                // `#[test] fn …` outside a test mod: skip its body.
                let mut j = i + 1;
                while j < f.toks.len() && f.punct(j) != Some('{') {
                    j += 1;
                }
                if f.punct(j) == Some('{') && f.fwd[j] != usize::MAX {
                    regions.push((j, f.fwd[j]));
                    i = f.fwd[j] + 1;
                    pending = false;
                    continue;
                }
                pending = false;
            }
            Tok::Ident(_) => pending = false,
            _ => {}
        }
        i += 1;
    }
    regions
}

fn in_test(regions: &[(usize, usize)], i: usize) -> bool {
    regions.iter().any(|(o, c)| i > *o && i < *c)
}

struct Decl {
    value: Option<u64>,
    line: u32,
    pattern_uses: u32,
    other_uses: u32,
}

pub fn check(files: &[SourceFile], allows: &[Allows], diags: &mut Vec<Diag>) {
    let find = |suffix: &str| files.iter().position(|f| f.path.ends_with(suffix));
    let Some(proto) = find("coordinator/protocol.rs") else { return };
    let use_files: Vec<usize> = [Some(proto), find("coordinator/service.rs"), find("src/rpc.rs")]
        .into_iter()
        .flatten()
        .collect();
    let match_files: Vec<usize> = [
        Some(proto),
        find("analytics/engine/plan.rs"),
        find("src/wirefmt.rs"),
    ]
    .into_iter()
    .flatten()
    .collect();

    // 1. Collect METHOD_* constants from protocol.rs.
    let mut decls: BTreeMap<String, Decl> = BTreeMap::new();
    {
        let f = &files[proto];
        let regions = test_regions(f);
        let mut i = 0;
        while i + 5 < f.toks.len() {
            if f.ident(i) == Some("const")
                && f.ident(i + 1).is_some_and(|n| n.starts_with("METHOD_"))
                && f.punct(i + 2) == Some(':')
                && !in_test(&regions, i)
            {
                // const NAME : TYPE = NUM ;
                let name = f.ident(i + 1).unwrap().to_string();
                let mut j = i + 3;
                while j < f.toks.len() && f.punct(j) != Some('=') && f.punct(j) != Some(';') {
                    j += 1;
                }
                let value = match f.toks.get(j + 1).map(|t| &t.tok) {
                    Some(Tok::Num(text)) if f.punct(j) == Some('=') => num_value(text),
                    _ => None,
                };
                decls.insert(
                    name,
                    Decl { value, line: f.line(i + 1), pattern_uses: 0, other_uses: 0 },
                );
            }
            i += 1;
        }
        // Duplicate values.
        let mut by_value: BTreeMap<u64, Vec<(&String, u32)>> = BTreeMap::new();
        for (name, d) in &decls {
            if let Some(v) = d.value {
                by_value.entry(v).or_default().push((name, d.line));
            }
        }
        for (v, names) in by_value {
            if names.len() > 1 {
                let (last, line) = names[names.len() - 1];
                if !allows[proto].allowed(RULE, line) {
                    let all: Vec<&str> = names.iter().map(|(n, _)| n.as_str()).collect();
                    diags.push(Diag {
                        file: f.path.clone(),
                        line,
                        rule: RULE,
                        msg: format!(
                            "duplicate wire tag {v:#x} shared by {} — `{last}` shadows the \
                             dispatch",
                            all.join(", ")
                        ),
                    });
                }
            }
        }
    }

    // 2. Usage audit over protocol.rs + service.rs + rpc.rs.
    for &fi in &use_files {
        let f = &files[fi];
        let regions = test_regions(f);
        for i in 0..f.toks.len() {
            let Some(name) = f.ident(i) else { continue };
            let Some(d) = decls.get_mut(name) else { continue };
            if in_test(&regions, i) {
                continue;
            }
            if fi == proto && f.line(i) == d.line {
                continue; // the declaration itself
            }
            if f.punct(i + 1) == Some('=') && f.punct(i + 2) == Some('>') {
                d.pattern_uses += 1;
            } else {
                d.other_uses += 1;
            }
        }
    }
    for (name, d) in &decls {
        if allows[proto].allowed(RULE, d.line) {
            continue;
        }
        let v = d.value.unwrap_or(0);
        if d.pattern_uses == 0 {
            diags.push(Diag {
                file: files[proto].path.clone(),
                line: d.line,
                rule: RULE,
                msg: format!(
                    "`{name}` ({v:#x}) is never matched in a decode path — frames with this \
                     tag fall through to the unknown-method reject"
                ),
            });
        }
        if d.other_uses == 0 {
            diags.push(Diag {
                file: files[proto].path.clone(),
                line: d.line,
                rule: RULE,
                msg: format!("`{name}` ({v:#x}) is declared and decoded but never sent"),
            });
        }
    }

    // 3. Tag matches must have a rejecting default arm.
    for &fi in &match_files {
        audit_matches(&files[fi], &allows[fi], diags);
    }
}

/// One parsed match arm: its pattern tokens and body token range.
struct Arm {
    pat: (usize, usize),
    body: (usize, usize),
}

fn audit_matches(f: &SourceFile, allows: &Allows, diags: &mut Vec<Diag>) {
    let regions = test_regions(f);
    for i in 0..f.toks.len() {
        if f.ident(i) != Some("match") || in_test(&regions, i) {
            continue;
        }
        // Scrutinee: first `{` at this level opens the match body.
        let mut j = i + 1;
        let mut body = None;
        while j < f.toks.len() {
            match &f.toks[j].tok {
                Tok::Punct('(') | Tok::Punct('[') if f.fwd[j] != usize::MAX => j = f.fwd[j],
                Tok::Punct('{') => {
                    body = Some(j);
                    break;
                }
                Tok::Punct(';') => break,
                _ => {}
            }
            j += 1;
        }
        let Some(open) = body else { continue };
        let close = f.fwd[open];
        if close == usize::MAX {
            continue;
        }
        let arms = parse_arms(f, open, close);
        let mut int_values: Vec<(u64, u32)> = Vec::new();
        let mut tag_arms = 0u32;
        let mut default: Option<&Arm> = None;
        for a in &arms {
            match classify_pattern(f, a.pat) {
                Pat::Ints(vs) => {
                    for v in vs {
                        int_values.push((v, f.line(a.pat.0)));
                    }
                }
                Pat::Tag => tag_arms += 1,
                Pat::Default => default = Some(a),
                Pat::Other => {}
            }
        }
        if (int_values.len() as u32 + tag_arms) < 2 {
            continue; // not a tag dispatch
        }
        let line = f.line(i);
        if allows.allowed(RULE, line) {
            continue;
        }
        // Duplicate literal arms.
        let mut seen: BTreeMap<u64, u32> = BTreeMap::new();
        for (v, vl) in &int_values {
            if let Some(first) = seen.get(v) {
                diags.push(Diag {
                    file: f.path.clone(),
                    line: *vl,
                    rule: RULE,
                    msg: format!(
                        "duplicate match arm for tag {v:#x} (first at line {first}) — this arm \
                         is dead"
                    ),
                });
            } else {
                seen.insert(*v, *vl);
            }
        }
        match default {
            None => diags.push(Diag {
                file: f.path.clone(),
                line,
                rule: RULE,
                msg: "tag match has no default arm — truncated or hostile bytes must hit an \
                      explicit reject (`t => bail!(…)`)"
                    .into(),
            }),
            Some(a) => {
                let rejects = (a.body.0..a.body.1).any(|k| {
                    matches!(
                        f.ident(k),
                        Some("bail" | "Err" | "err" | "panic" | "unreachable")
                    )
                });
                if !rejects {
                    diags.push(Diag {
                        file: f.path.clone(),
                        line: f.line(a.pat.0),
                        rule: RULE,
                        msg: "tag match default arm does not reject — unknown tags must \
                              produce an error, not a silent fallback"
                            .into(),
                    });
                }
            }
        }
    }
}

fn parse_arms(f: &SourceFile, open: usize, close: usize) -> Vec<Arm> {
    let mut arms = Vec::new();
    let mut j = open + 1;
    while j < close {
        // Pattern: tokens up to `=>` at this level.
        let pat_start = j;
        let mut arrow = None;
        while j < close {
            match &f.toks[j].tok {
                Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{')
                    if f.fwd[j] != usize::MAX =>
                {
                    j = f.fwd[j]
                }
                Tok::Punct('=') if f.punct(j + 1) == Some('>') => {
                    arrow = Some(j);
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        let Some(arrow) = arrow else { break };
        // Body: a block, or tokens up to `,` at this level.
        let body_start = arrow + 2;
        let body_end;
        if f.punct(body_start) == Some('{') && f.fwd[body_start] != usize::MAX {
            body_end = f.fwd[body_start];
            j = body_end + 1;
            if f.punct(j) == Some(',') {
                j += 1;
            }
        } else {
            let mut k = body_start;
            while k < close {
                match &f.toks[k].tok {
                    Tok::Punct('(') | Tok::Punct('[') | Tok::Punct('{')
                        if f.fwd[k] != usize::MAX =>
                    {
                        k = f.fwd[k]
                    }
                    Tok::Punct(',') => break,
                    _ => {}
                }
                k += 1;
            }
            body_end = k;
            j = k + 1;
        }
        arms.push(Arm { pat: (pat_start, arrow), body: (body_start, body_end) });
    }
    arms
}

enum Pat {
    /// Integer-literal arm(s): `0x51`, `1 | 2`.
    Ints(Vec<u64>),
    /// A `METHOD_*` constant pattern.
    Tag,
    /// `_` or a lone lowercase binding.
    Default,
    Other,
}

fn classify_pattern(f: &SourceFile, (start, end): (usize, usize)) -> Pat {
    let toks: Vec<&Tok> = (start..end).map(|k| &f.toks[k].tok).collect();
    match toks.as_slice() {
        [Tok::Punct('_')] => Pat::Default,
        [Tok::Ident(s)] if s.starts_with("METHOD_") => Pat::Tag,
        [Tok::Ident(s)]
            if s.chars().next().is_some_and(|c| c.is_lowercase() || c == '_') =>
        {
            Pat::Default
        }
        _ => {
            // `N`, `N | M | …` — all tokens must be nums or `|`.
            let mut vals = Vec::new();
            for t in &toks {
                match t {
                    Tok::Num(text) => {
                        if let Some(v) = num_value(text) {
                            vals.push(v);
                        } else {
                            return Pat::Other;
                        }
                    }
                    Tok::Punct('|') => {}
                    _ => return Pat::Other,
                }
            }
            if vals.is_empty() {
                Pat::Other
            } else {
                Pat::Ints(vals)
            }
        }
    }
}

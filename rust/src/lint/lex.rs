//! Minimal Rust tokenizer for the invariant checker — the same
//! hand-rolled zero-dependency style as [`crate::analytics::sql::lex`],
//! but for Rust source instead of SQL text.
//!
//! The rules downstream only need a faithful token stream with line
//! numbers plus the comments (the allowlist lives in comments), so this
//! lexer is deliberately lossy where it can afford to be: string and
//! char literals keep no content, numeric literals keep raw text (for
//! tag-value comparison), and every other non-ident character becomes a
//! single-character [`Tok::Punct`]. What it must not be lossy about:
//! comment boundaries (including nested `/* /* */ */`), raw strings
//! (`r#"…"#` may contain `//` and braces), and the lifetime-vs-char
//! ambiguity of `'` — getting any of those wrong desynchronizes every
//! brace-matching pass built on top.

/// One lexed token.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`fn`, `impl`, `queries`, …).
    Ident(String),
    /// Numeric literal, raw text preserved (`0x51`, `1_000`, `2.5`).
    Num(String),
    /// String literal (content dropped; raw/byte strings included).
    Str,
    /// Char literal (content dropped).
    Char,
    /// Lifetime (`'a`, `'static`).
    Life,
    /// Any other single character (`{`, `.`, `=`, `#`, …).
    Punct(char),
}

/// A token with the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
}

/// A comment with the 1-based line it starts on. `text` excludes the
/// `//` / `/*` markers.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Lex `src` into tokens plus the comment list.
pub fn lex(src: &str) -> (Vec<Token>, Vec<Comment>) {
    let cs: Vec<char> = src.chars().collect();
    let mut toks = Vec::new();
    let mut comments = Vec::new();
    let mut line: u32 = 1;
    let mut i = 0;
    while i < cs.len() {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut j = start;
            while j < cs.len() && cs[j] != '\n' {
                j += 1;
            }
            comments.push(Comment { line, text: cs[start..j].iter().collect() });
            i = j;
            continue;
        }
        // Block comment, possibly nested.
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let start_line = line;
            let start = i + 2;
            let mut j = start;
            let mut depth = 1usize;
            while j < cs.len() && depth > 0 {
                if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            let end = j.saturating_sub(2).max(start);
            comments.push(Comment { line: start_line, text: cs[start..end].iter().collect() });
            i = j;
            continue;
        }
        // Raw / byte strings: r"…", r#"…"#, br"…", b"…".
        if c == 'r' || c == 'b' {
            let (skip, is_raw) = match (c, cs.get(i + 1), cs.get(i + 2)) {
                ('r', Some(&'"'), _) | ('r', Some(&'#'), _) => (1, true),
                ('b', Some(&'r'), Some(&'"')) | ('b', Some(&'r'), Some(&'#')) => (2, true),
                ('b', Some(&'"'), _) => (1, false),
                ('b', Some(&'\''), _) => {
                    // Byte char literal b'x'.
                    toks.push(Token { tok: Tok::Char, line });
                    i = skip_char_literal(&cs, i + 1, &mut line);
                    continue;
                }
                _ => (0, false),
            };
            // `r#ident` raw identifiers share the `r#` prefix with raw
            // strings — only commit once the opening quote is seen.
            let mut j = i + skip;
            let mut hashes = 0usize;
            while is_raw && cs.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            if is_raw && cs.get(j) == Some(&'"') {
                j += 1;
                // Scan for `"` followed by `hashes` hash marks.
                'outer: while j < cs.len() {
                    if cs[j] == '\n' {
                        line += 1;
                    } else if cs[j] == '"' {
                        for k in 0..hashes {
                            if cs.get(j + 1 + k) != Some(&'#') {
                                j += 1;
                                continue 'outer;
                            }
                        }
                        j += 1 + hashes;
                        break;
                    }
                    j += 1;
                }
                toks.push(Token { tok: Tok::Str, line });
                i = j;
                continue;
            }
            if skip == 1 && c == 'b' {
                // b"…": plain string with a byte prefix.
                let start_line = line;
                i = skip_string(&cs, i + 2, &mut line);
                toks.push(Token { tok: Tok::Str, line: start_line });
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }
        if c == '"' {
            let start_line = line;
            i = skip_string(&cs, i + 1, &mut line);
            toks.push(Token { tok: Tok::Str, line: start_line });
            continue;
        }
        if c == '\'' {
            // Lifetime if followed by ident-start NOT closing with a
            // quote right after ('a vs 'a'). `'_'` is a char pattern in
            // theory but `'_` the placeholder lifetime in practice.
            let next = cs.get(i + 1).copied().unwrap_or(' ');
            let after = cs.get(i + 2).copied().unwrap_or(' ');
            if (next.is_alphabetic() || next == '_') && after != '\'' {
                let mut j = i + 1;
                while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                toks.push(Token { tok: Tok::Life, line });
                i = j;
                continue;
            }
            toks.push(Token { tok: Tok::Char, line });
            i = skip_char_literal(&cs, i, &mut line);
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < cs.len() && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Token { tok: Tok::Ident(cs[i..j].iter().collect()), line });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < cs.len() {
                let d = cs[j];
                if d.is_alphanumeric() || d == '_' {
                    j += 1;
                } else if d == '.'
                    && cs.get(j + 1).is_some_and(|n| n.is_ascii_digit())
                {
                    // Float continuation, but never `0..n` ranges.
                    j += 1;
                } else {
                    break;
                }
            }
            toks.push(Token { tok: Tok::Num(cs[i..j].iter().collect()), line });
            i = j;
            continue;
        }
        toks.push(Token { tok: Tok::Punct(c), line });
        i += 1;
    }
    (toks, comments)
}

/// Skip a string body starting just past the opening quote; returns the
/// index just past the closing quote.
fn skip_string(cs: &[char], mut j: usize, line: &mut u32) -> usize {
    while j < cs.len() {
        match cs[j] {
            '\\' => j += 2,
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Skip a char literal starting at the opening `'`; returns the index
/// just past the closing `'`.
fn skip_char_literal(cs: &[char], i: usize, line: &mut u32) -> usize {
    let mut j = i + 1;
    while j < cs.len() {
        match cs[j] {
            '\\' => j += 2,
            '\'' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Parse a numeric literal's value: handles `0x`/`0o`/`0b` radixes,
/// `_` separators, and type suffixes (`0x51u32`). Returns `None` for
/// floats or malformed text.
pub fn num_value(text: &str) -> Option<u64> {
    let t: String = text.chars().filter(|c| *c != '_').collect();
    let (radix, digits) = if let Some(rest) = t.strip_prefix("0x").or_else(|| t.strip_prefix("0X"))
    {
        (16, rest)
    } else if let Some(rest) = t.strip_prefix("0b").or_else(|| t.strip_prefix("0B")) {
        (2, rest)
    } else if let Some(rest) = t.strip_prefix("0o").or_else(|| t.strip_prefix("0O")) {
        (8, rest)
    } else {
        (10, t.as_str())
    };
    // Take digit chars valid in this radix; the remainder must be a
    // type suffix (starts with a letter outside the radix set).
    let end = digits
        .char_indices()
        .find(|(_, c)| !c.is_digit(radix))
        .map(|(i, _)| i)
        .unwrap_or(digits.len());
    if end == 0 {
        return None;
    }
    let (num, suffix) = digits.split_at(end);
    if !suffix.is_empty() && !suffix.chars().next().is_some_and(|c| c.is_alphabetic()) {
        return None; // e.g. a float's `.5` tail
    }
    u64::from_str_radix(num, radix).ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .0
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn basic_tokens_and_lines() {
        let (toks, comments) = lex("fn f() {\n  x.lock(); // held\n}\n");
        assert!(matches!(&toks[0].tok, Tok::Ident(s) if s == "fn"));
        let lock = toks.iter().find(|t| t.tok == Tok::Ident("lock".into())).unwrap();
        assert_eq!(lock.line, 2);
        assert_eq!(comments.len(), 1);
        assert_eq!(comments[0].line, 2);
        assert_eq!(comments[0].text.trim(), "held");
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let (toks, comments) = lex("/* a /* b */ c */ let s = r#\"no // comment {\"#;");
        assert_eq!(comments.len(), 1);
        assert!(comments[0].text.contains("b"));
        // The raw string swallowed its contents: no brace puncts.
        assert!(!toks.iter().any(|t| t.tok == Tok::Punct('{')));
        assert!(toks.iter().any(|t| t.tok == Tok::Str));
    }

    #[test]
    fn lifetime_vs_char() {
        let (toks, _) = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifes = toks.iter().filter(|t| t.tok == Tok::Life).count();
        let chars = toks.iter().filter(|t| t.tok == Tok::Char).count();
        assert_eq!(lifes, 2);
        assert_eq!(chars, 2);
    }

    #[test]
    fn ranges_do_not_eat_dots() {
        let (toks, _) = lex("for i in 0..n { a[i] = 2.5; }");
        assert!(toks.iter().any(|t| t.tok == Tok::Num("0".into())));
        assert!(toks.iter().any(|t| t.tok == Tok::Num("2.5".into())));
        assert_eq!(toks.iter().filter(|t| t.tok == Tok::Punct('.')).count(), 2);
    }

    #[test]
    fn num_values() {
        assert_eq!(num_value("0x51"), Some(0x51));
        assert_eq!(num_value("0x5A"), Some(0x5A));
        assert_eq!(num_value("81u32"), Some(81));
        assert_eq!(num_value("1_000"), Some(1000));
        assert_eq!(num_value("0b1010"), Some(10));
        assert_eq!(num_value("2.5"), None);
    }

    #[test]
    fn keywords_are_idents() {
        assert_eq!(idents("impl Foo for Bar {}"), vec!["impl", "Foo", "for", "Bar"]);
    }
}

//! RULE `no-panic-worker` — worker wire-decode and plan-compile paths
//! must reject hostile input with a typed `Error` (surfaced as an
//! error Ack over the fabric), never a panic. A panicking worker
//! thread on a headless NIC is a silent capacity loss; the PR 5
//! invariant is that any byte sequence a peer can send produces either
//! a result or an error frame.
//!
//! Roots: every non-test method of `WorkerShared` (the worker's frame
//! handlers), the `decode`/`dec_*` codec fns in `protocol.rs`,
//! `plan.rs`, and `partial.rs`, `compile`/`compile_scan` in `plan.rs`,
//! and all of `wirefmt.rs` (the primitive reader every codec trusts).
//!
//! Flagged: `.unwrap()` / `.expect(…)` (except directly on `.lock()`,
//! where propagating mutex poisoning is the repo-wide policy),
//! `panic!`/`unreachable!`/`todo!`/`unimplemented!`, and — in codec
//! fns (`wirefmt.rs` or fns named `decode`/`dec_*`) — slice indexing
//! without a `// bound:` comment proving the bound on the same or the
//! preceding line. `debug_assert!` is fine (compiled out in release);
//! leader-side code is out of scope (a leader panic is loud and
//! local, not a silent fleet-side loss).

use super::fns::{Extracted, FnInfo, Resolver, SourceFile};
use super::lex::Tok;
use super::{Allows, Diag};
use std::collections::VecDeque;

pub const RULE: &str = "no-panic-worker";

const SCOPE: &[&str] = &[
    "coordinator/service.rs",
    "coordinator/protocol.rs",
    "src/wirefmt.rs",
    "analytics/engine/plan.rs",
    "analytics/engine/partial.rs",
];

const PANIC_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

const INDEX_PREV_KEYWORDS: &[&str] = &[
    "let", "in", "return", "else", "match", "if", "while", "for", "mut", "ref", "move", "as",
    "box", "unsafe", "use", "pub", "fn", "where", "loop", "break", "continue",
];

fn in_scope(path: &str) -> bool {
    SCOPE.iter().any(|s| path.ends_with(s))
}

fn is_root(f: &FnInfo, path: &str) -> bool {
    if f.is_test {
        return false;
    }
    let decode_name =
        |n: &str| n.contains("decode") || n.starts_with("dec_");
    if path.ends_with("coordinator/service.rs") {
        return f.impl_ty.as_deref() == Some("WorkerShared");
    }
    if path.ends_with("coordinator/protocol.rs") {
        return decode_name(&f.name);
    }
    if path.ends_with("analytics/engine/plan.rs") {
        return decode_name(&f.name) || f.name == "compile" || f.name == "compile_scan";
    }
    if path.ends_with("analytics/engine/partial.rs") {
        return decode_name(&f.name);
    }
    if path.ends_with("src/wirefmt.rs") {
        return true;
    }
    false
}

/// Does the indexing sub-check apply to this fn?
fn checks_indexing(f: &FnInfo, path: &str) -> bool {
    path.ends_with("src/wirefmt.rs") || f.name.contains("decode") || f.name.starts_with("dec_")
}

pub fn check(files: &[SourceFile], ex: &Extracted, allows: &[Allows], diags: &mut Vec<Diag>) {
    let scope: Vec<bool> = ex.fns.iter().map(|f| in_scope(&files[f.file].path)).collect();
    let resolver = Resolver::new(&ex.fns, &scope);

    let mut reached = vec![false; ex.fns.len()];
    let mut queue: VecDeque<usize> = VecDeque::new();
    for (i, f) in ex.fns.iter().enumerate() {
        if scope[i] && is_root(f, &files[f.file].path) {
            reached[i] = true;
            queue.push_back(i);
        }
    }
    while let Some(i) = queue.pop_front() {
        let f = &ex.fns[i];
        for c in &f.calls {
            if let Some(g) = resolver.resolve(f, c) {
                if !reached[g] {
                    reached[g] = true;
                    queue.push_back(g);
                }
            }
        }
    }

    for (i, f) in ex.fns.iter().enumerate() {
        if reached[i] {
            scan_fn(files, f, &allows[f.file], diags);
        }
    }
}

fn scan_fn(files: &[SourceFile], f: &FnInfo, allows: &Allows, diags: &mut Vec<Diag>) {
    let file = &files[f.file];
    let (open, close) = f.body;
    let indexing = checks_indexing(f, &file.path);
    let mut flag = |line: u32, msg: String, diags: &mut Vec<Diag>| {
        if allows.allowed(RULE, line) {
            return;
        }
        diags.push(Diag { file: file.path.clone(), line, rule: RULE, msg });
    };
    for i in (open + 1)..close {
        match &file.toks[i].tok {
            Tok::Ident(m)
                if (m == "unwrap" || m == "expect")
                    && file.punct(i.wrapping_sub(1)) == Some('.')
                    && file.punct(i + 1) == Some('(') =>
            {
                // `.lock().unwrap()` propagates mutex poisoning — the
                // repo-wide policy, exempt by design.
                let on_lock = file.ident(i.wrapping_sub(4)) == Some("lock")
                    && file.punct(i.wrapping_sub(3)) == Some('(')
                    && file.punct(i.wrapping_sub(2)) == Some(')');
                if !on_lock {
                    flag(
                        file.line(i),
                        format!(
                            "`.{m}()` in `{}` on a worker decode/compile path — return a typed \
                             Error (error Ack) or add `// lint: allow({RULE}) reason`",
                            f.qual()
                        ),
                        diags,
                    );
                }
            }
            Tok::Ident(m)
                if PANIC_MACROS.contains(&m.as_str()) && file.punct(i + 1) == Some('!') =>
            {
                flag(
                    file.line(i),
                    format!(
                        "`{m}!` in `{}` on a worker decode/compile path — return a typed Error \
                         (error Ack) instead",
                        f.qual()
                    ),
                    diags,
                );
            }
            Tok::Punct('[') if indexing => {
                // Index expression: `expr[...]` — previous token ends
                // an expression (ident, `)`, or `]`), not a pattern,
                // type, or attribute position.
                let is_index = match &file.toks[i.wrapping_sub(1)].tok {
                    Tok::Ident(p) => !INDEX_PREV_KEYWORDS.contains(&p.as_str()),
                    Tok::Punct(')') | Tok::Punct(']') => true,
                    _ => false,
                };
                if is_index && !allows.bound(file.line(i)) {
                    flag(
                        file.line(i),
                        format!(
                            "unchecked slice index in codec fn `{}` — prove the bound in a \
                             `// bound:` comment on this or the preceding line, or return an \
                             error on short input",
                            f.qual()
                        ),
                        diags,
                    );
                }
            }
            _ => {}
        }
    }
}

//! RULE `lock-order` — the inter-lock acquisition graph over the
//! coordinator must be acyclic and respect the canonical order.
//!
//! The canonical order is written in exactly one place in the checked
//! tree (the `LeaderShared` doc comment in `coordinator/service.rs`)
//! and encoded exactly once here, in [`CANONICAL`]: `queries` before
//! `dead` before `sched`, with `last_heard` leaf-only (never held
//! while acquiring anything — the monitor reads it on every beat, so
//! any lock taken under it inherits heartbeat latency).
//!
//! Edges come from two sources: a second `.lock()` while a guard is
//! live in the same body, and a call made while a guard is live whose
//! callee (transitively, via the resolver) acquires a lock. Locks are
//! identified by receiver *field name* — two distinct mutexes sharing
//! a field name would unify, which is why the checked scope is the
//! coordinator plus `rpc.rs`/`exec.rs` where names are unique.

use super::fns::{Extracted, Resolver, SourceFile};
use super::{Allows, Diag};
use std::collections::{BTreeMap, BTreeSet};

pub const RULE: &str = "lock-order";

/// Canonical acquisition order, outermost first.
pub const CANONICAL: &[&str] = &["queries", "dead", "sched"];

/// Locks that may never be held across another acquisition.
pub const LEAF_ONLY: &[&str] = &["last_heard"];

fn in_scope(path: &str) -> bool {
    path.contains("/coordinator/")
        || path.ends_with("src/rpc.rs")
        || path.ends_with("src/exec.rs")
}

/// A lock acquisition attributable to a source site.
#[derive(Clone)]
struct Site {
    file: usize,
    line: u32,
}

pub fn check(
    files: &[SourceFile],
    ex: &Extracted,
    allows: &[Allows],
    diags: &mut Vec<Diag>,
) {
    let scope: Vec<bool> =
        ex.fns.iter().map(|f| in_scope(&files[f.file].path)).collect();
    let resolver = Resolver::new(&ex.fns, &scope);

    // Transitive closure of acquisitions per fn: lock name -> one
    // witness site. Fixpoint iteration; the graph is tiny.
    let n = ex.fns.len();
    let mut closure: Vec<BTreeMap<String, Site>> = vec![BTreeMap::new(); n];
    for (i, f) in ex.fns.iter().enumerate() {
        if !scope[i] || f.is_test {
            continue;
        }
        for a in &f.acqs {
            closure[i]
                .entry(a.lock.clone())
                .or_insert(Site { file: f.file, line: a.line });
        }
    }
    let callees: Vec<Vec<usize>> = ex
        .fns
        .iter()
        .enumerate()
        .map(|(i, f)| {
            if !scope[i] || f.is_test {
                return Vec::new();
            }
            f.calls.iter().filter_map(|c| resolver.resolve(f, c)).collect()
        })
        .collect();
    loop {
        let mut changed = false;
        for i in 0..n {
            for &g in &callees[i] {
                let add: Vec<(String, Site)> = closure[g]
                    .iter()
                    .filter(|(k, _)| !closure[i].contains_key(*k))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect();
                if !add.is_empty() {
                    changed = true;
                    closure[i].extend(add);
                }
            }
        }
        if !changed {
            break;
        }
    }

    // Collect edges: (held, acquired) -> first witness + provenance.
    let mut edges: BTreeMap<(String, String), (Site, String)> = BTreeMap::new();
    let mut add_edge = |held: &str, lock: &str, site: Site, how: String| {
        if allows[site.file].allowed(RULE, site.line) {
            return;
        }
        edges
            .entry((held.to_string(), lock.to_string()))
            .or_insert((site, how));
    };
    for (i, f) in ex.fns.iter().enumerate() {
        if !scope[i] || f.is_test {
            continue;
        }
        for e in &f.edges {
            add_edge(
                &e.held,
                &e.lock,
                Site { file: f.file, line: e.line },
                format!("in `{}`", f.qual()),
            );
        }
        for c in &f.calls {
            if c.held.is_empty() {
                continue;
            }
            let Some(g) = resolver.resolve(f, c) else { continue };
            for (lock, site) in &closure[g] {
                for held in &c.held {
                    add_edge(
                        held,
                        lock,
                        site.clone(),
                        format!(
                            "via `{}` -> `{}` at {}:{}",
                            f.qual(),
                            ex.fns[g].qual(),
                            files[f.file].path,
                            c.line
                        ),
                    );
                }
            }
        }
    }

    let mut out: BTreeSet<(String, u32, String)> = BTreeSet::new();
    let pos = |l: &str| CANONICAL.iter().position(|c| *c == l);
    for ((held, lock), (site, how)) in &edges {
        let path = files[site.file].path.clone();
        if held == lock {
            out.insert((
                path,
                site.line,
                format!("`{held}` re-acquired while already held ({how}) — self-deadlock"),
            ));
            continue;
        }
        if LEAF_ONLY.contains(&held.as_str()) {
            out.insert((
                path,
                site.line,
                format!(
                    "`{lock}` acquired while `{held}` is held ({how}) — `{held}` is leaf-only"
                ),
            ));
            continue;
        }
        if let (Some(ph), Some(pl)) = (pos(held), pos(lock)) {
            if ph > pl {
                out.insert((
                    path,
                    site.line,
                    format!(
                        "`{lock}` acquired while `{held}` is held ({how}) — canonical order is {}",
                        CANONICAL.join(" < ")
                    ),
                ));
            }
        }
    }

    // Cycle detection over the remaining (non-self) edge graph.
    let nodes: BTreeSet<&str> = edges
        .keys()
        .flat_map(|(a, b)| [a.as_str(), b.as_str()])
        .collect();
    let nodes: Vec<&str> = nodes.into_iter().collect();
    let idx: BTreeMap<&str, usize> = nodes.iter().enumerate().map(|(i, n)| (*n, i)).collect();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for (a, b) in edges.keys() {
        if a != b {
            adj[idx[a.as_str()]].push(idx[b.as_str()]);
        }
    }
    for cyc in collect_cycles(&adj) {
        let names: Vec<&str> = cyc.iter().map(|&i| nodes[i]).collect();
        let mut parts = Vec::new();
        for w in 0..names.len() {
            let a = names[w];
            let b = names[(w + 1) % names.len()];
            if let Some((site, _)) = edges.get(&(a.to_string(), b.to_string())) {
                parts.push(format!("{} -> {} at {}:{}", a, b, files[site.file].path, site.line));
            }
        }
        // Anchor the diag at the first edge's site.
        let first = edges
            .get(&(names[0].to_string(), names[1 % names.len()].to_string()))
            .map(|(s, _)| (files[s.file].path.clone(), s.line))
            .unwrap_or_default();
        out.insert((
            first.0,
            first.1,
            format!("lock cycle: {} -> {} ({})", names.join(" -> "), names[0], parts.join("; ")),
        ));
    }

    for (file, line, msg) in out {
        diags.push(Diag { file, line, rule: RULE, msg });
    }
}

/// Find elementary cycles, one representative per distinct node set.
fn collect_cycles(adj: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let n = adj.len();
    let mut found: Vec<Vec<usize>> = Vec::new();
    let mut seen_sets: BTreeSet<Vec<usize>> = BTreeSet::new();
    for start in 0..n {
        // DFS from `start`, only visiting nodes >= start (canonical
        // smallest-node representative per cycle).
        let mut stack: Vec<(usize, usize)> = vec![(start, 0)];
        let mut path: Vec<usize> = vec![start];
        let mut on_path = vec![false; n];
        on_path[start] = true;
        while let Some((node, ei)) = stack.last_mut() {
            if let Some(&next) = adj[*node].get(*ei) {
                *ei += 1;
                if next == start {
                    let mut key = path.clone();
                    key.sort_unstable();
                    if seen_sets.insert(key) {
                        found.push(path.clone());
                    }
                } else if next > start && !on_path[next] {
                    on_path[next] = true;
                    path.push(next);
                    stack.push((next, 0));
                }
            } else {
                on_path[*node] = false;
                path.pop();
                stack.pop();
            }
        }
    }
    found
}

//! RULE `hot-path-alloc` — allocating constructs must not be reachable
//! from the per-morsel kernels or the pooled-frame encode paths.
//!
//! The runtime `alloc_regression.rs` gate counts allocations on the
//! paths the bench actually drives; this rule covers the code it never
//! executes (rare branches, error paths, new call sites added later).
//! Roots are the batch kernels (`fold_range`, `eval_into`,
//! `update_sel`, …) and the wire-encode entry points that write into
//! pooled buffers. Reachability follows the shared resolver; closure
//! dispatch (`(c.eval)(…)`) is opaque to a tokenizer, so compiled-
//! expression bodies are covered at their definition sites (they are
//! roots themselves) rather than through the indirect call.
//!
//! `reserve`/`extend_from_slice`/`push` are deliberately not flagged:
//! growing a caller-provided, pooled buffer is the sanctioned pattern
//! (amortized to zero in steady state and measured by the runtime
//! gate); what the rule rejects is constructing fresh owned storage
//! per call.

use super::fns::{Extracted, FnInfo, Resolver, SourceFile};
use super::lex::Tok;
use super::{Allows, Diag};
use std::collections::VecDeque;

pub const RULE: &str = "hot-path-alloc";

/// (file suffix, fn name) pairs that anchor the reachability walk.
const ROOTS: &[(&str, &str)] = &[
    ("analytics/engine/mod.rs", "fold_range"),
    ("analytics/engine/mod.rs", "fold_sel"),
    ("analytics/engine/mod.rs", "select_pruned"),
    ("analytics/engine/mod.rs", "run_range_scratch"),
    ("analytics/engine/mod.rs", "aggregate_sel_scratch"),
    ("analytics/engine/expr.rs", "eval_into"),
    ("analytics/engine/agg.rs", "update_sel"),
    ("analytics/engine/partial.rs", "encode_into"),
    ("coordinator/protocol.rs", "encode_parts_into"),
    ("src/rpc.rs", "frame_with"),
    ("src/rpc.rs", "cast_frame"),
    ("src/rpc.rs", "get"),
    ("src/rpc.rs", "put"),
];

/// Files whose fns participate in resolution and scanning.
const SCOPE: &[&str] = &[
    "analytics/engine/mod.rs",
    "analytics/engine/expr.rs",
    "analytics/engine/agg.rs",
    "analytics/engine/join.rs",
    "analytics/engine/partial.rs",
    "analytics/ops.rs",
    "coordinator/protocol.rs",
    "src/rpc.rs",
    "src/wirefmt.rs",
];

const ALLOC_TYPES: &[&str] =
    &["Vec", "String", "Box", "HashMap", "HashSet", "VecDeque", "BTreeMap", "BTreeSet"];
const ALLOC_CTORS: &[&str] = &["new", "with_capacity", "from"];
const ALLOC_METHODS: &[&str] = &["collect", "to_vec", "to_owned", "to_string", "clone"];
const ALLOC_MACROS: &[&str] = &["vec", "format"];

fn in_scope(path: &str) -> bool {
    SCOPE.iter().any(|s| path.ends_with(s))
}

pub fn check(files: &[SourceFile], ex: &Extracted, allows: &[Allows], diags: &mut Vec<Diag>) {
    let scope: Vec<bool> = ex.fns.iter().map(|f| in_scope(&files[f.file].path)).collect();
    let resolver = Resolver::new(&ex.fns, &scope);

    // BFS from the roots; remember which root first reached each fn.
    let mut root_of: Vec<Option<usize>> = vec![None; ex.fns.len()];
    let mut queue: VecDeque<usize> = VecDeque::new();
    for (i, f) in ex.fns.iter().enumerate() {
        if f.is_test || !scope[i] {
            continue;
        }
        let is_root = ROOTS
            .iter()
            .any(|(suf, name)| files[f.file].path.ends_with(suf) && f.name == *name);
        if is_root {
            root_of[i] = Some(i);
            queue.push_back(i);
        }
    }
    while let Some(i) = queue.pop_front() {
        let f = &ex.fns[i];
        for c in &f.calls {
            if let Some(g) = resolver.resolve(f, c) {
                if root_of[g].is_none() {
                    root_of[g] = root_of[i];
                    queue.push_back(g);
                }
            }
        }
    }

    for (i, f) in ex.fns.iter().enumerate() {
        let Some(root) = root_of[i] else { continue };
        scan_fn(files, f, &ex.fns[root], &allows[f.file], diags);
    }
}

fn scan_fn(
    files: &[SourceFile],
    f: &FnInfo,
    root: &FnInfo,
    allows: &Allows,
    diags: &mut Vec<Diag>,
) {
    let file = &files[f.file];
    let (open, close) = f.body;
    let mut flag = |line: u32, what: &str, diags: &mut Vec<Diag>| {
        if allows.allowed(RULE, line) {
            return;
        }
        diags.push(Diag {
            file: file.path.clone(),
            line,
            rule: RULE,
            msg: format!(
                "{what} allocates on a hot path (reachable from root `{}` via `{}`) — reuse \
                 caller-provided buffers or add `// lint: allow({RULE}) reason`",
                root.qual(),
                f.qual()
            ),
        });
    };
    let mut i = open + 1;
    while i < close {
        match &file.toks[i].tok {
            Tok::Ident(m)
                if ALLOC_MACROS.contains(&m.as_str()) && file.punct(i + 1) == Some('!') =>
            {
                flag(file.line(i), &format!("`{m}!`"), diags);
            }
            Tok::Ident(t)
                if ALLOC_TYPES.contains(&t.as_str())
                    && file.punct(i + 1) == Some(':')
                    && file.punct(i + 2) == Some(':')
                    && file
                        .ident(i + 3)
                        .is_some_and(|m| ALLOC_CTORS.contains(&m)) =>
            {
                let m = file.ident(i + 3).unwrap();
                flag(file.line(i), &format!("`{t}::{m}`"), diags);
                i += 4;
                continue;
            }
            Tok::Ident(m)
                if ALLOC_METHODS.contains(&m.as_str())
                    && file.punct(i.wrapping_sub(1)) == Some('.')
                    && (file.punct(i + 1) == Some('(')
                        || (file.punct(i + 1) == Some(':')
                            && file.punct(i + 2) == Some(':'))) =>
            {
                flag(file.line(i), &format!("`.{m}()`"), diags);
            }
            _ => {}
        }
        i += 1;
    }
}

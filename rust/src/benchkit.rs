//! Measurement harness for `cargo bench` targets.
//!
//! The offline registry has no `criterion`, so this module provides the
//! pieces the paper-reproduction benches need: warmup + timed iterations
//! with robust statistics, fixed-format result tables (so EXPERIMENTS.md
//! rows can be pasted from bench output), and simple throughput helpers.
//!
//! Benches are plain binaries with `harness = false`; each calls
//! [`Bench::new`] and registers measurements or model-derived rows.

use std::alloc::{GlobalAlloc, Layout, System};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// A counting wrapper over the system allocator, installable as
/// `#[global_allocator]` in a bench or test binary:
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: lovelock::benchkit::CountingAlloc =
///     lovelock::benchkit::CountingAlloc::new();
/// ```
///
/// [`CountingAlloc::allocations`] reads the number of allocation events
/// (alloc + realloc + alloc_zeroed; frees are not counted) since process
/// start. The hotpath bench uses it to report allocations per morsel,
/// and the `alloc_regression` test pins the engine's steady-state fold
/// at exactly zero. Process-wide: measure on a single thread with no
/// concurrent work, or the count includes everyone else's allocations.
pub struct CountingAlloc;

static ALLOC_EVENTS: AtomicU64 = AtomicU64::new(0);

impl CountingAlloc {
    pub const fn new() -> Self {
        CountingAlloc
    }

    /// Allocation events since process start.
    pub fn allocations() -> u64 {
        ALLOC_EVENTS.load(Ordering::Relaxed)
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        Self::new()
    }
}

// SAFETY: delegates verbatim to `System`; the only addition is a relaxed
// atomic increment, which allocates nothing and cannot unwind.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Robust summary statistics over a set of per-iteration timings.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub n: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn from_ns(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        Self {
            n,
            mean_ns: mean,
            median_ns: percentile(&samples, 50.0),
            p95_ns: percentile(&samples, 95.0),
            min_ns: samples[0],
            max_ns: samples[n - 1],
            stddev_ns: var.sqrt(),
        }
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Format a nanosecond quantity with an adaptive unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Format a byte/s throughput adaptively.
pub fn fmt_bps(bytes_per_sec: f64) -> String {
    const GB: f64 = 1e9;
    const MB: f64 = 1e6;
    if bytes_per_sec >= GB {
        format!("{:.2} GB/s", bytes_per_sec / GB)
    } else {
        format!("{:.2} MB/s", bytes_per_sec / MB)
    }
}

/// A single named measurement (or model-derived row) in a bench report.
#[derive(Clone, Debug)]
pub struct Row {
    pub name: String,
    pub value: String,
    pub detail: String,
}

/// Bench context: runs closures with warmup + timed iterations and collects
/// a fixed-format report printed at the end (and on drop).
pub struct Bench {
    title: String,
    rows: Vec<Row>,
    warmup: Duration,
    min_iters: usize,
    max_iters: usize,
    target: Duration,
}

impl Bench {
    pub fn new(title: &str) -> Self {
        // Quick mode keeps `cargo bench` turnaround reasonable in CI.
        let quick = std::env::var("LOVELOCK_BENCH_QUICK").is_ok();
        Self {
            title: title.to_string(),
            rows: Vec::new(),
            warmup: if quick { Duration::from_millis(20) } else { Duration::from_millis(200) },
            min_iters: if quick { 3 } else { 10 },
            max_iters: if quick { 20 } else { 200 },
            target: if quick { Duration::from_millis(100) } else { Duration::from_secs(1) },
        }
    }

    /// Time `f` (warmup until `self.warmup` elapsed, then iterate until the
    /// target duration or max iterations) and record a row.
    pub fn measure<F: FnMut()>(&mut self, name: &str, mut f: F) -> Stats {
        // Warmup.
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            f();
        }
        // Timed iterations.
        let mut samples = Vec::new();
        let t0 = Instant::now();
        while (samples.len() < self.min_iters)
            || (t0.elapsed() < self.target && samples.len() < self.max_iters)
        {
            let s = Instant::now();
            f();
            samples.push(s.elapsed().as_nanos() as f64);
        }
        let stats = Stats::from_ns(samples);
        self.rows.push(Row {
            name: name.to_string(),
            value: fmt_ns(stats.median_ns),
            detail: format!(
                "mean {} p95 {} n={}",
                fmt_ns(stats.mean_ns),
                fmt_ns(stats.p95_ns),
                stats.n
            ),
        });
        stats
    }

    /// Time `f` and report throughput over `bytes` processed per call.
    pub fn measure_throughput<F: FnMut()>(&mut self, name: &str, bytes: u64, mut f: F) -> f64 {
        let stats = {
            // Same loop as `measure` but we format as bandwidth.
            let w0 = Instant::now();
            while w0.elapsed() < self.warmup {
                f();
            }
            let mut samples = Vec::new();
            let t0 = Instant::now();
            while (samples.len() < self.min_iters)
                || (t0.elapsed() < self.target && samples.len() < self.max_iters)
            {
                let s = Instant::now();
                f();
                samples.push(s.elapsed().as_nanos() as f64);
            }
            Stats::from_ns(samples)
        };
        let bps = bytes as f64 / (stats.median_ns / 1e9);
        self.rows.push(Row {
            name: name.to_string(),
            value: fmt_bps(bps),
            detail: format!("median {} over {} B/iter", fmt_ns(stats.median_ns), bytes),
        });
        bps
    }

    /// Record a model-derived (non-timed) row — used by the analytic
    /// reproductions (cost model, projections).
    pub fn row(
        &mut self,
        name: &str,
        value: impl std::fmt::Display,
        detail: impl std::fmt::Display,
    ) {
        self.rows.push(Row {
            name: name.to_string(),
            value: value.to_string(),
            detail: detail.to_string(),
        });
    }

    /// Render the report table.
    pub fn report(&self) -> String {
        let name_w = self.rows.iter().map(|r| r.name.len()).max().unwrap_or(4).max(4);
        let val_w = self.rows.iter().map(|r| r.value.len()).max().unwrap_or(5).max(5);
        let mut out = String::new();
        let _ = writeln!(out, "\n=== {} ===", self.title);
        let _ = writeln!(out, "{:<name_w$}  {:>val_w$}  {}", "name", "value", "detail");
        let _ = writeln!(out, "{}  {}  {}", "-".repeat(name_w), "-".repeat(val_w), "-".repeat(24));
        for r in &self.rows {
            let _ = writeln!(out, "{:<name_w$}  {:>val_w$}  {}", r.name, r.value, r.detail);
        }
        out
    }

    pub fn finish(self) {
        println!("{}", self.report());
    }

    /// Serialize the report rows as a JSON array (machine-readable bench
    /// artifacts; no serde offline, so the writer is hand-rolled).
    pub fn to_json(&self) -> String {
        let mut s = String::from("[\n");
        for (i, r) in self.rows.iter().enumerate() {
            let _ = write!(
                s,
                "  {{\"name\": \"{}\", \"value\": \"{}\", \"detail\": \"{}\"}}",
                json_escape(&r.name),
                json_escape(&r.value),
                json_escape(&r.detail)
            );
            s.push_str(if i + 1 < self.rows.len() { ",\n" } else { "\n" });
        }
        s.push_str("]\n");
        s
    }

    /// Print the report and also write it as JSON to `path` (e.g.
    /// `BENCH_hotpath.json`). A write failure is reported on stderr but
    /// does not fail the bench.
    pub fn finish_json(self, path: &str) {
        println!("{}", self.report());
        if let Err(e) = std::fs::write(path, self.to_json()) {
            eprintln!("warning: could not write {path}: {e}");
        } else {
            println!("(wrote {path})");
        }
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// Prevent the optimizer from eliding a computed value (stable-rust
/// equivalent of `criterion::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counting_alloc_counts_alloc_events_only() {
        let a = CountingAlloc::new();
        let before = CountingAlloc::allocations();
        unsafe {
            let layout = Layout::from_size_align(64, 8).unwrap();
            let p = a.alloc(layout);
            assert!(!p.is_null());
            let p = a.realloc(p, layout, 128);
            assert!(!p.is_null());
            let grown = Layout::from_size_align(128, 8).unwrap();
            a.dealloc(p, grown);
        }
        // alloc + realloc counted; dealloc not.
        assert_eq!(CountingAlloc::allocations(), before + 2);
    }

    #[test]
    fn stats_basic() {
        let s = Stats::from_ns(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean_ns - 3.0).abs() < 1e-9);
        assert!((s.median_ns - 3.0).abs() < 1e-9);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert!((percentile(&v, 50.0) - 5.0).abs() < 1e-9);
        assert!((percentile(&v, 95.0) - 9.5).abs() < 1e-9);
    }

    #[test]
    fn fmt_units() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.50 ms");
        assert_eq!(fmt_ns(3.0e9), "3.000 s");
        assert_eq!(fmt_bps(2.0e9), "2.00 GB/s");
        assert_eq!(fmt_bps(5.0e6), "5.00 MB/s");
    }

    #[test]
    fn measure_runs_and_records() {
        std::env::set_var("LOVELOCK_BENCH_QUICK", "1");
        let mut b = Bench::new("t");
        let mut acc = 0u64;
        let st = b.measure("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(st.n >= 3);
        assert!(b.report().contains("noop"));
    }

    #[test]
    fn row_renders() {
        let mut b = Bench::new("t");
        b.row("cost_ratio", format!("{:.2}x", 2.31), "phi=3 mu=1.2");
        let rep = b.report();
        assert!(rep.contains("cost_ratio"));
        assert!(rep.contains("2.31x"));
    }

    #[test]
    fn json_report_escapes_and_lists_rows() {
        let mut b = Bench::new("t");
        b.row("a \"quoted\" name", "1.0", "line1\nline2");
        b.row("plain", "2 GB/s", "ok");
        let j = b.to_json();
        assert!(j.starts_with("[\n") && j.ends_with("]\n"), "{j}");
        assert!(j.contains("a \\\"quoted\\\" name"));
        assert!(j.contains("line1\\nline2"));
        assert!(j.contains("\"value\": \"2 GB/s\""));
        // Two rows → exactly one separating comma line.
        assert_eq!(j.matches("},\n").count(), 1);
    }

    #[test]
    fn json_escape_controls() {
        assert_eq!(json_escape("a\tb\\c"), "a\\tb\\\\c");
        assert_eq!(json_escape("\u{1}"), "\\u0001");
    }
}

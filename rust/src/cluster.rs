//! Cluster model: the composition of a Lovelock (or traditional) cluster.
//!
//! A cluster is a set of [`Node`]s — each a server or a smart NIC — with a
//! role per §3 of the paper: *accelerator node* (attached GPUs/TPUs),
//! *storage node* (attached SSDs), or *lite compute* node (no peripherals;
//! shuffles and lightweight compute). [`ClusterSpec::lovelock_from`] builds
//! the Lovelock replacement of a traditional cluster with a given φ, and
//! the aggregate accessors feed the cost model, the fabric simulator, and
//! the coordinator's placement decisions.

use crate::costmodel::CostModel;
use crate::platform::{self, Kind, Platform};
use crate::simnet::Topology;

/// Role of a node (what hangs off its PCIe, if anything).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Drives attached accelerators (GPU/TPU/video/crypto).
    Accelerator { count: u32 },
    /// Serves attached storage devices over the network.
    Storage { devices: u32 },
    /// No peripherals: lightweight compute and data shuffles.
    LiteCompute,
}

/// One node in a cluster.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub platform: Platform,
    pub role: Role,
}

impl Node {
    /// PCIe-device count (accelerators or SSDs).
    pub fn peripheral_count(&self) -> u32 {
        match self.role {
            Role::Accelerator { count } => count,
            Role::Storage { devices } => devices,
            Role::LiteCompute => 0,
        }
    }
}

/// A whole cluster: homogeneous platform per spec (matching the paper's
/// comparisons), arbitrary role mix.
#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub name: String,
    pub nodes: Vec<Node>,
    /// Fabric description used to instantiate `simnet`.
    pub nodes_per_rack: usize,
    pub tor_uplink_gbps: f64,
}

impl ClusterSpec {
    /// A traditional server-centric cluster of `n` identical nodes.
    pub fn traditional(n: usize, platform: Platform, role: Role) -> Self {
        let host_gbps = platform.nic_gbps;
        let nodes = (0..n)
            .map(|id| Node { id, platform: platform.clone(), role })
            .collect();
        let nodes_per_rack = 16.min(n.max(1));
        Self {
            name: format!("traditional-{n}x-{}", platform.name),
            nodes,
            nodes_per_rack,
            // Non-oversubscribed by default.
            tor_uplink_gbps: nodes_per_rack as f64 * host_gbps,
        }
    }

    /// The Lovelock replacement: φ smart NICs per original server, same
    /// peripherals redistributed across the NICs of each group.
    pub fn lovelock_from(orig: &ClusterSpec, phi: u32, nic: Platform) -> Self {
        assert!(phi >= 1);
        assert_eq!(nic.kind, Kind::SmartNic);
        let mut nodes = Vec::with_capacity(orig.nodes.len() * phi as usize);
        for server in &orig.nodes {
            let total = server.peripheral_count();
            for j in 0..phi {
                // Distribute peripherals round-robin across the φ NICs.
                let share = total / phi + u32::from(j < total % phi);
                let role = match server.role {
                    Role::Accelerator { .. } => {
                        if share > 0 {
                            Role::Accelerator { count: share }
                        } else {
                            Role::LiteCompute
                        }
                    }
                    Role::Storage { .. } => {
                        if share > 0 {
                            Role::Storage { devices: share }
                        } else {
                            Role::LiteCompute
                        }
                    }
                    Role::LiteCompute => Role::LiteCompute,
                };
                nodes.push(Node { id: nodes.len(), platform: nic.clone(), role });
            }
        }
        let nodes_per_rack = (orig.nodes_per_rack * phi as usize).min(nodes.len().max(1));
        Self {
            name: format!("lovelock-phi{phi}-{}", nic.name),
            nodes,
            nodes_per_rack,
            tor_uplink_gbps: nodes_per_rack as f64 * nic.nic_gbps,
        }
    }

    /// Convenience: Lovelock with IPU E2000 NICs.
    pub fn lovelock_e2000(orig: &ClusterSpec, phi: u32) -> Self {
        Self::lovelock_from(orig, phi, platform::ipu_e2000())
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// The node platform of this cluster (specs are homogeneous per the
    /// paper's comparisons). Panics on an empty cluster.
    pub fn platform(&self) -> &Platform {
        &self.nodes.first().expect("cluster has no nodes").platform
    }

    /// Aggregate end-host network bandwidth, Gbit/s — the quantity §5.2's
    /// argument turns on.
    pub fn aggregate_nic_gbps(&self) -> f64 {
        self.nodes.iter().map(|n| n.platform.nic_gbps).sum()
    }

    /// Aggregate DRAM bandwidth, GB/s.
    pub fn aggregate_dram_gbs(&self) -> f64 {
        self.nodes.iter().map(|n| n.platform.dram_gbs()).sum()
    }

    /// Aggregate vCPU count.
    pub fn total_vcpus(&self) -> u32 {
        self.nodes.iter().map(|n| n.platform.vcpus).sum()
    }

    /// Total peripherals (must be conserved by the Lovelock transform).
    pub fn total_peripherals(&self) -> u32 {
        self.nodes.iter().map(|n| n.peripheral_count()).sum()
    }

    /// Relative capital cost of this cluster (sum of node + peripheral
    /// relative costs under the paper's model; peripherals cost `c_p_each`
    /// relative to a smart NIC).
    pub fn relative_cost(&self, c_p_each: f64) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.platform.rel_cost + n.peripheral_count() as f64 * c_p_each)
            .sum()
    }

    /// Relative power of this cluster.
    pub fn relative_power(&self, p_p_each: f64) -> f64 {
        self.nodes
            .iter()
            .map(|n| n.platform.rel_power + n.peripheral_count() as f64 * p_p_each)
            .sum()
    }

    /// Build the `simnet` topology for this cluster.
    pub fn topology(&self) -> Topology {
        let racks = self.num_nodes().div_ceil(self.nodes_per_rack);
        let host_gbps = self.nodes.first().map(|n| n.platform.nic_gbps).unwrap_or(100.0);
        Topology::new(racks.max(1), self.nodes_per_rack, host_gbps, self.tor_uplink_gbps)
    }

    /// Cost ratio vs another cluster via the paper's per-device model.
    pub fn cost_ratio_vs(&self, lovelock: &ClusterSpec, model: &CostModel) -> f64 {
        let per_periph = if self.total_peripherals() > 0 {
            model.c_p / (self.total_peripherals() as f64 / self.num_nodes() as f64)
        } else {
            0.0
        };
        self.relative_cost(per_periph) / lovelock.relative_cost(per_periph)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::n2d_milan;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn traditional_cluster_shape() {
        let c = ClusterSpec::traditional(8, n2d_milan(), Role::Accelerator { count: 4 });
        assert_eq!(c.num_nodes(), 8);
        assert_eq!(c.total_peripherals(), 32);
        assert!(close(c.aggregate_nic_gbps(), 800.0, 1e-9));
        assert_eq!(c.total_vcpus(), 8 * 224);
        assert_eq!(c.platform().name, n2d_milan().name);
    }

    #[test]
    fn lovelock_conserves_peripherals() {
        let orig = ClusterSpec::traditional(8, n2d_milan(), Role::Accelerator { count: 4 });
        for phi in [1, 2, 3, 4] {
            let l = ClusterSpec::lovelock_e2000(&orig, phi);
            assert_eq!(l.num_nodes(), 8 * phi as usize);
            assert_eq!(l.total_peripherals(), 32, "phi={phi}");
        }
    }

    #[test]
    fn lovelock_phi2_doubles_nodes_and_quadruples_bandwidth() {
        // Milan servers have 100 Gbps; E2000 has 200 Gbps → φ=2 gives
        // 2 × 2 = 4× aggregate end-host bandwidth.
        let orig = ClusterSpec::traditional(4, n2d_milan(), Role::LiteCompute);
        let l = ClusterSpec::lovelock_e2000(&orig, 2);
        assert!(close(l.aggregate_nic_gbps() / orig.aggregate_nic_gbps(), 4.0, 1e-9));
    }

    #[test]
    fn phi3_with_4_accels_distributes_2_1_1() {
        let orig = ClusterSpec::traditional(1, n2d_milan(), Role::Accelerator { count: 4 });
        let l = ClusterSpec::lovelock_e2000(&orig, 3);
        let counts: Vec<u32> = l.nodes.iter().map(|n| n.peripheral_count()).collect();
        assert_eq!(counts, vec![2, 1, 1]);
        // Nodes with accelerators keep the Accelerator role.
        assert!(matches!(l.nodes[0].role, Role::Accelerator { count: 2 }));
    }

    #[test]
    fn phi_above_peripherals_leaves_lite_nodes() {
        let orig = ClusterSpec::traditional(1, n2d_milan(), Role::Accelerator { count: 2 });
        let l = ClusterSpec::lovelock_e2000(&orig, 4);
        let lite = l.nodes.iter().filter(|n| n.role == Role::LiteCompute).count();
        assert_eq!(lite, 2);
        assert_eq!(l.total_peripherals(), 2);
    }

    #[test]
    fn relative_cost_matches_eq1_shape() {
        // Bare cluster: cost ratio = c_s / φ.
        let orig = ClusterSpec::traditional(10, n2d_milan(), Role::LiteCompute);
        let l3 = ClusterSpec::lovelock_e2000(&orig, 3);
        let ratio = orig.relative_cost(0.0) / l3.relative_cost(0.0);
        assert!(close(ratio, 7.0 / 3.0, 1e-9));
        // Power likewise.
        let p = orig.relative_power(0.0) / l3.relative_power(0.0);
        assert!(close(p, 11.2 / 3.0, 1e-9));
    }

    #[test]
    fn topology_covers_all_nodes() {
        let orig = ClusterSpec::traditional(20, n2d_milan(), Role::LiteCompute);
        let t = orig.topology();
        assert!(t.num_nodes() >= orig.num_nodes());
        let l = ClusterSpec::lovelock_e2000(&orig, 3);
        assert!(l.topology().num_nodes() >= 60);
    }

    #[test]
    fn storage_role_distributes() {
        let orig = ClusterSpec::traditional(2, n2d_milan(), Role::Storage { devices: 8 });
        let l = ClusterSpec::lovelock_e2000(&orig, 2);
        assert_eq!(l.total_peripherals(), 16);
        assert!(l.nodes.iter().all(|n| matches!(n.role, Role::Storage { devices: 4 })));
    }

    #[test]
    fn smartnic_platform_enforced() {
        let orig = ClusterSpec::traditional(1, n2d_milan(), Role::LiteCompute);
        let result = std::panic::catch_unwind(|| {
            ClusterSpec::lovelock_from(&orig, 2, n2d_milan())
        });
        assert!(result.is_err());
    }
}

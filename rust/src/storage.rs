//! Disaggregated storage substrate.
//!
//! In Lovelock, a *storage node* is a smart NIC with several SSDs serving
//! requests over the network. This module provides (a) an in-memory object
//! store with SSD bandwidth/IOPS accounting (the simulated device), and
//! (b) a [`StorageNode`] that fronts a set of devices and reports the
//! service time of each request so the coordinator can overlay storage I/O
//! onto the fabric simulation.

use std::collections::HashMap;
use std::sync::Mutex;

/// Performance envelope of one storage device.
#[derive(Clone, Copy, Debug)]
pub struct DeviceSpec {
    /// Sequential read bandwidth, bytes/s.
    pub read_bps: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bps: f64,
    /// Per-request fixed latency, seconds.
    pub latency_s: f64,
}

impl DeviceSpec {
    /// A datacenter NVMe SSD: 3.2 GB/s read, 2.0 GB/s write, 80 µs.
    pub fn nvme() -> Self {
        Self { read_bps: 3.2e9, write_bps: 2.0e9, latency_s: 80e-6 }
    }

    /// A capacity HDD: 250 MB/s, 8 ms.
    pub fn hdd() -> Self {
        Self { read_bps: 250e6, write_bps: 220e6, latency_s: 8e-3 }
    }
}

/// One simulated device: stores object bytes and accounts busy time.
struct Device {
    spec: DeviceSpec,
    /// device-time at which the device next becomes free (seconds).
    busy_until: f64,
    bytes_read: u64,
    bytes_written: u64,
}

/// Result of a storage request.
#[derive(Clone, Copy, Debug)]
pub struct IoResult {
    /// When the device completed the request (device timeline, seconds).
    pub complete_at: f64,
    /// Pure service time (latency + transfer).
    pub service_s: f64,
    pub bytes: u64,
}

/// A storage node: object key → (device, bytes), striped over devices.
pub struct StorageNode {
    devices: Mutex<Vec<Device>>,
    objects: Mutex<HashMap<String, (usize, Vec<u8>)>>,
    next_device: Mutex<usize>,
}

impl StorageNode {
    pub fn new(n_devices: usize, spec: DeviceSpec) -> Self {
        assert!(n_devices > 0);
        Self {
            devices: Mutex::new(
                (0..n_devices)
                    .map(|_| Device { spec, busy_until: 0.0, bytes_read: 0, bytes_written: 0 })
                    .collect(),
            ),
            objects: Mutex::new(HashMap::new()),
            next_device: Mutex::new(0),
        }
    }

    pub fn num_devices(&self) -> usize {
        self.devices.lock().unwrap().len()
    }

    /// Write an object at simulated time `now`; round-robin placement.
    pub fn put(&self, key: &str, data: Vec<u8>, now: f64) -> IoResult {
        let dev_idx = {
            let mut g = self.next_device.lock().unwrap();
            let i = *g;
            *g = (*g + 1) % self.num_devices();
            i
        };
        let bytes = data.len() as u64;
        let service = {
            let mut devs = self.devices.lock().unwrap();
            let d = &mut devs[dev_idx];
            let start = d.busy_until.max(now);
            let service = d.spec.latency_s + bytes as f64 / d.spec.write_bps;
            d.busy_until = start + service;
            d.bytes_written += bytes;
            IoResult { complete_at: start + service, service_s: service, bytes }
        };
        self.objects.lock().unwrap().insert(key.to_string(), (dev_idx, data));
        service
    }

    /// Read an object at simulated time `now`.
    pub fn get(&self, key: &str, now: f64) -> Option<(Vec<u8>, IoResult)> {
        let (dev_idx, data) = {
            let objs = self.objects.lock().unwrap();
            let (i, d) = objs.get(key)?;
            (*i, d.clone())
        };
        let bytes = data.len() as u64;
        let mut devs = self.devices.lock().unwrap();
        let d = &mut devs[dev_idx];
        let start = d.busy_until.max(now);
        let service = d.spec.latency_s + bytes as f64 / d.spec.read_bps;
        d.busy_until = start + service;
        d.bytes_read += bytes;
        Some((data, IoResult { complete_at: start + service, service_s: service, bytes }))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.objects.lock().unwrap().contains_key(key)
    }

    pub fn delete(&self, key: &str) -> bool {
        self.objects.lock().unwrap().remove(key).is_some()
    }

    /// Total bytes stored.
    pub fn stored_bytes(&self) -> u64 {
        self.objects.lock().unwrap().values().map(|(_, d)| d.len() as u64).sum()
    }

    /// (bytes_read, bytes_written) across devices.
    pub fn io_totals(&self) -> (u64, u64) {
        let devs = self.devices.lock().unwrap();
        devs.iter().fold((0, 0), |(r, w), d| (r + d.bytes_read, w + d.bytes_written))
    }

    /// Aggregate sequential read bandwidth of the node, bytes/s.
    pub fn aggregate_read_bps(&self) -> f64 {
        let devs = self.devices.lock().unwrap();
        devs.iter().map(|d| d.spec.read_bps).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn put_get_roundtrip() {
        let node = StorageNode::new(4, DeviceSpec::nvme());
        let data = vec![42u8; 1024];
        node.put("obj/1", data.clone(), 0.0);
        let (got, _) = node.get("obj/1", 0.0).unwrap();
        assert_eq!(got, data);
        assert!(node.contains("obj/1"));
        assert!(!node.contains("obj/2"));
    }

    #[test]
    fn read_timing_matches_spec() {
        let node = StorageNode::new(1, DeviceSpec::nvme());
        let mb = vec![0u8; 3_200_000]; // 3.2 MB → 1 ms transfer
        node.put("k", mb, 0.0);
        let (_, io) = node.get("k", 1.0).unwrap();
        assert!(close(io.service_s, 80e-6 + 1e-3, 1e-9));
    }

    #[test]
    fn device_queueing_serializes() {
        // Two reads on the same (single) device queue behind each other.
        let node = StorageNode::new(1, DeviceSpec::nvme());
        node.put("a", vec![0u8; 3_200_000], 0.0);
        let w = node.get("a", 10.0).unwrap().1; // starts at max(busy, 10.0)
        let x = node.get("a", 10.0).unwrap().1;
        assert!(x.complete_at > w.complete_at);
        assert!(close(x.complete_at - w.complete_at, w.service_s, 1e-9));
    }

    #[test]
    fn striping_round_robins() {
        let node = StorageNode::new(4, DeviceSpec::nvme());
        for i in 0..8 {
            node.put(&format!("k{i}"), vec![0u8; 100], 0.0);
        }
        // With 4 devices and 8 objects, reads of k0..k3 queue on distinct
        // devices → identical start times.
        let times: Vec<f64> = (0..4)
            .map(|i| node.get(&format!("k{i}"), 1.0).unwrap().1.complete_at)
            .collect();
        for t in &times {
            assert!(close(*t, times[0], 1e-9));
        }
    }

    #[test]
    fn totals_account() {
        let node = StorageNode::new(2, DeviceSpec::nvme());
        node.put("a", vec![1u8; 500], 0.0);
        node.put("b", vec![2u8; 300], 0.0);
        node.get("a", 0.0);
        let (r, w) = node.io_totals();
        assert_eq!(w, 800);
        assert_eq!(r, 500);
        assert_eq!(node.stored_bytes(), 800);
        assert!(node.delete("a"));
        assert_eq!(node.stored_bytes(), 300);
        assert!(!node.delete("a"));
    }

    #[test]
    fn hdd_slower_than_nvme() {
        let nvme = StorageNode::new(1, DeviceSpec::nvme());
        let hdd = StorageNode::new(1, DeviceSpec::hdd());
        nvme.put("k", vec![0u8; 10_000_000], 0.0);
        hdd.put("k", vec![0u8; 10_000_000], 0.0);
        let t_nvme = nvme.get("k", 100.0).unwrap().1.service_s;
        let t_hdd = hdd.get("k", 100.0).unwrap().1.service_s;
        assert!(t_hdd > 10.0 * t_nvme);
    }

    #[test]
    fn aggregate_bandwidth_scales_with_devices() {
        let node = StorageNode::new(4, DeviceSpec::nvme());
        assert!(close(node.aggregate_read_bps(), 4.0 * 3.2e9, 1.0));
    }
}

//! BigQuery-style execution-time projection — Figure 4 of the paper.
//!
//! The paper takes the published breakdown of Google BigQuery processing
//! time (Gonzalez et al., ISCA'23 [19]): on average >60% of wall time is
//! network (remote shuffle + disaggregated storage I/O), the rest CPU.
//! Projection onto a Lovelock cluster with φ smart NICs per server:
//!
//! * CPU time × `cpu_ratio / φ` — `cpu_ratio` is the whole-host CPU
//!   performance of a traditional server relative to one E2000 (the
//!   median 4.7× from Figure 3), and aggregate smart-NIC compute scales
//!   linearly with φ;
//! * shuffle and storage-I/O time × `1/φ` — these are network-bandwidth
//!   bound, and aggregate end-host bandwidth scales with φ.
//!
//! The resulting total is the paper's μ: 1.22 at φ=2, 0.81 at φ=3.

use crate::costmodel::CostModel;

/// Normalized execution-time breakdown of the baseline (traditional)
/// cluster. Components must sum to 1.
#[derive(Clone, Copy, Debug)]
pub struct Breakdown {
    pub cpu: f64,
    pub shuffle: f64,
    pub storage_io: f64,
}

impl Breakdown {
    /// The breakdown consistent with [19] and the paper's Fig. 4 numbers:
    /// CPU 39%, network 61% (shuffle 36% + storage I/O 25%). RPC
    /// processing is attributed to CPU per the paper.
    pub fn isca23() -> Self {
        Self { cpu: 0.39, shuffle: 0.36, storage_io: 0.25 }
    }

    pub fn total(&self) -> f64 {
        self.cpu + self.shuffle + self.storage_io
    }

    pub fn network_fraction(&self) -> f64 {
        self.shuffle + self.storage_io
    }
}

/// Projected execution-time composition on a Lovelock cluster.
#[derive(Clone, Copy, Debug)]
pub struct Projection {
    pub phi: f64,
    pub cpu: f64,
    pub shuffle: f64,
    pub storage_io: f64,
}

impl Projection {
    /// Total normalized time = the paper's μ.
    pub fn mu(&self) -> f64 {
        self.cpu + self.shuffle + self.storage_io
    }
}

/// Project the baseline breakdown onto Lovelock with φ NICs per server.
///
/// `cpu_ratio` is the whole-host CPU performance of one traditional server
/// relative to one smart NIC (Fig. 3 median: 4.7 for Milan).
pub fn project(b: &Breakdown, phi: f64, cpu_ratio: f64) -> Projection {
    assert!(phi > 0.0 && cpu_ratio > 0.0);
    Projection {
        phi,
        cpu: b.cpu * cpu_ratio / phi,
        shuffle: b.shuffle / phi,
        storage_io: b.storage_io / phi,
    }
}

/// Figure 4 rows: baseline plus Lovelock at the given φ values.
pub fn figure4(b: &Breakdown, phis: &[f64], cpu_ratio: f64) -> Vec<Projection> {
    let mut rows = vec![Projection { phi: 1.0 / cpu_ratio, ..Default::default() }];
    rows.clear();
    rows.push(Projection { phi: 0.0, cpu: b.cpu, shuffle: b.shuffle, storage_io: b.storage_io });
    for &phi in phis {
        rows.push(project(b, phi, cpu_ratio));
    }
    rows
}

impl Default for Projection {
    fn default() -> Self {
        Self { phi: 0.0, cpu: 0.0, shuffle: 0.0, storage_io: 0.0 }
    }
}

/// §5.2's cost/energy summary for a Fig. 4 configuration: lite-compute
/// nodes (no PCIe devices), cost from Eq. 1 and energy from Eq. 2 with the
/// projected μ.
pub fn cost_energy_for(phi: f64, mu: f64) -> (f64, f64) {
    let m = CostModel::host_only();
    (m.cost_ratio(phi), m.power_ratio(phi, mu))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn breakdown_sums_to_one_and_network_heavy() {
        let b = Breakdown::isca23();
        assert!(close(b.total(), 1.0, 1e-12));
        // Paper: "over 60% of total time is spent on network operations".
        assert!(b.network_fraction() > 0.60);
    }

    /// Paper: φ=2 → μ=1.22 (22% slower).
    #[test]
    fn phi2_matches_paper() {
        let p = project(&Breakdown::isca23(), 2.0, 4.7);
        assert!(close(p.mu(), 1.22, 0.01), "mu={}", p.mu());
    }

    /// Paper: φ=3 → μ=0.81 (19% faster).
    #[test]
    fn phi3_matches_paper() {
        let p = project(&Breakdown::isca23(), 3.0, 4.7);
        assert!(close(p.mu(), 0.81, 0.01), "mu={}", p.mu());
    }

    /// Paper: CPU-side slowdown at φ=2 is 4.7/2 = 2.35× on the CPU term.
    #[test]
    fn cpu_term_scales() {
        let b = Breakdown::isca23();
        let p = project(&b, 2.0, 4.7);
        assert!(close(p.cpu / b.cpu, 2.35, 1e-9));
        assert!(close(p.shuffle / b.shuffle, 0.5, 1e-9));
    }

    /// §5.2 cost/energy: 3.5× (φ=2), 2.33× (φ=3); energy ≈4.58× both.
    #[test]
    fn cost_energy_match_paper() {
        let mu2 = project(&Breakdown::isca23(), 2.0, 4.7).mu();
        let (c2, e2) = cost_energy_for(2.0, mu2);
        assert!(close(c2, 3.5, 0.01));
        assert!(close(e2, 4.58, 0.08), "e2={e2}");
        let mu3 = project(&Breakdown::isca23(), 3.0, 4.7).mu();
        let (c3, e3) = cost_energy_for(3.0, mu3);
        assert!(close(c3, 2.33, 0.01));
        assert!(close(e3, 4.58, 0.08), "e3={e3}");
    }

    #[test]
    fn figure4_has_baseline_plus_rows() {
        let rows = figure4(&Breakdown::isca23(), &[2.0, 3.0], 4.7);
        assert_eq!(rows.len(), 3);
        assert!(close(rows[0].mu(), 1.0, 1e-12));
        assert!(rows[1].mu() > rows[2].mu()); // φ=3 faster than φ=2
    }

    #[test]
    fn mu_monotone_decreasing_in_phi() {
        let b = Breakdown::isca23();
        let mut last = f64::INFINITY;
        for phi in [1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 6.0] {
            let mu = project(&b, phi, 4.7).mu();
            assert!(mu < last);
            last = mu;
        }
    }

    #[test]
    fn breakeven_phi_between_2_and_3() {
        // The crossover (μ = 1) the figure shows lies between φ=2 and φ=3.
        let b = Breakdown::isca23();
        let mu_at = |phi: f64| project(&b, phi, 4.7).mu();
        assert!(mu_at(2.0) > 1.0 && mu_at(3.0) < 1.0);
    }
}

//! Flow-level datacenter-fabric simulator.
//!
//! Lovelock's §5.2/§6 arguments are about *aggregate end-host bandwidth*
//! and *fabric capacity*: replacing one server (one NIC) with φ smart NICs
//! multiplies end-host ports, while the ToR/fabric may be oversubscribed.
//! This simulator models exactly that altitude: nodes with host links,
//! two-tier topology (ToR uplinks to a non-blocking core), flows that
//! share links by **max-min fairness** (progressive filling), and an
//! event-driven loop that advances simulated time between flow arrivals
//! and completions. Shuffle and storage traffic in the coordinator run on
//! top of it.

use std::collections::BTreeMap;

/// Identifier of a node (a server or a smart NIC).
pub type NodeId = usize;
/// Identifier of a flow.
pub type FlowId = usize;

/// Two-tier topology: `racks × nodes_per_rack` hosts; each rack's ToR has
/// an aggregated uplink (per direction) to a non-blocking core.
#[derive(Clone, Debug)]
pub struct Topology {
    pub racks: usize,
    pub nodes_per_rack: usize,
    /// Host link rate per node, Gbit/s (full duplex: modeled per direction).
    pub host_gbps: f64,
    /// ToR uplink aggregate per direction, Gbit/s.
    pub tor_uplink_gbps: f64,
}

impl Topology {
    pub fn new(racks: usize, nodes_per_rack: usize, host_gbps: f64, tor_uplink_gbps: f64) -> Self {
        assert!(racks > 0 && nodes_per_rack > 0 && host_gbps > 0.0 && tor_uplink_gbps > 0.0);
        Self { racks, nodes_per_rack, host_gbps, tor_uplink_gbps }
    }

    /// Non-oversubscribed fabric for `n` nodes in one logical rack.
    pub fn flat(n: usize, host_gbps: f64) -> Self {
        Self::new(1, n, host_gbps, host_gbps * n as f64)
    }

    pub fn num_nodes(&self) -> usize {
        self.racks * self.nodes_per_rack
    }

    pub fn rack_of(&self, n: NodeId) -> usize {
        n / self.nodes_per_rack
    }

    /// Oversubscription ratio: worst-case rack egress demand over uplink.
    pub fn oversubscription(&self) -> f64 {
        self.nodes_per_rack as f64 * self.host_gbps / self.tor_uplink_gbps
    }
}

/// Links are identified structurally for the fairness computation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
enum Link {
    HostUp(NodeId),
    HostDown(NodeId),
    TorUp(usize),
    TorDown(usize),
}

/// One flow: `bytes` from `src` to `dst`, injected at `start` (seconds).
#[derive(Clone, Debug)]
pub struct Flow {
    pub id: FlowId,
    pub src: NodeId,
    pub dst: NodeId,
    pub bytes: f64,
    pub start: f64,
}

/// Completion record for a finished flow.
#[derive(Clone, Copy, Debug)]
pub struct FlowDone {
    pub id: FlowId,
    pub start: f64,
    pub finish: f64,
    pub bytes: f64,
}

impl FlowDone {
    pub fn duration(&self) -> f64 {
        self.finish - self.start
    }
    /// Average achieved goodput, Gbit/s.
    pub fn gbps(&self) -> f64 {
        self.bytes * 8.0 / self.duration() / 1e9
    }
}

/// The simulator: add flows, then [`Simulation::run`].
pub struct Simulation {
    topo: Topology,
    flows: Vec<Flow>,
    next_id: FlowId,
}

impl Simulation {
    pub fn new(topo: Topology) -> Self {
        Self { topo, flows: Vec::new(), next_id: 0 }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Queue a flow; returns its id. `src == dst` flows complete instantly
    /// (local loopback — infinite bandwidth at this altitude).
    pub fn add_flow(&mut self, src: NodeId, dst: NodeId, bytes: f64, start: f64) -> FlowId {
        assert!(src < self.topo.num_nodes() && dst < self.topo.num_nodes());
        assert!(bytes >= 0.0 && start >= 0.0);
        let id = self.next_id;
        self.next_id += 1;
        self.flows.push(Flow { id, src, dst, bytes, start });
        id
    }

    fn links_of(&self, f: &Flow) -> Vec<Link> {
        let (sr, dr) = (self.topo.rack_of(f.src), self.topo.rack_of(f.dst));
        let mut ls = vec![Link::HostUp(f.src), Link::HostDown(f.dst)];
        if sr != dr {
            ls.push(Link::TorUp(sr));
            ls.push(Link::TorDown(dr));
        }
        ls
    }

    /// Max-min fair rates (bytes/s) for the given active flow indices.
    fn rates(&self, active: &[usize]) -> Vec<f64> {
        // Capacities in bytes/s.
        let cap_of = |l: Link| -> f64 {
            match l {
                Link::HostUp(_) | Link::HostDown(_) => self.topo.host_gbps * 1e9 / 8.0,
                Link::TorUp(_) | Link::TorDown(_) => self.topo.tor_uplink_gbps * 1e9 / 8.0,
            }
        };
        let mut remaining: BTreeMap<Link, (f64, usize)> = BTreeMap::new();
        let mut flow_links: Vec<Vec<Link>> = Vec::with_capacity(active.len());
        for &fi in active {
            let ls = self.links_of(&self.flows[fi]);
            for &l in &ls {
                let e = remaining.entry(l).or_insert((cap_of(l), 0));
                e.1 += 1;
            }
            flow_links.push(ls);
        }
        let mut rate = vec![0.0f64; active.len()];
        let mut fixed = vec![false; active.len()];
        let mut unfixed = active.len();
        // Progressive filling: repeatedly saturate the tightest link.
        while unfixed > 0 {
            // Find the link with the smallest fair share among links that
            // still carry unfixed flows.
            let mut best: Option<(f64, Link)> = None;
            for (&l, &(cap, cnt)) in &remaining {
                if cnt == 0 {
                    continue;
                }
                let share = cap / cnt as f64;
                if best.map(|(s, _)| share < s).unwrap_or(true) {
                    best = Some((share, l));
                }
            }
            let (share, bottleneck) = match best {
                Some(b) => b,
                None => break,
            };
            // Fix every unfixed flow crossing the bottleneck at `share`.
            for (ai, links) in flow_links.iter().enumerate() {
                if fixed[ai] || !links.contains(&bottleneck) {
                    continue;
                }
                fixed[ai] = true;
                unfixed -= 1;
                rate[ai] = share;
                for &l in links {
                    let e = remaining.get_mut(&l).unwrap();
                    e.0 = (e.0 - share).max(0.0);
                    e.1 -= 1;
                }
            }
        }
        rate
    }

    /// Run to completion of all flows; returns per-flow records sorted by
    /// id. Zero-byte and loopback flows complete at their start time.
    pub fn run(&mut self) -> Vec<FlowDone> {
        let mut done: Vec<FlowDone> = Vec::with_capacity(self.flows.len());
        let mut remaining: Vec<f64> = self.flows.iter().map(|f| f.bytes).collect();
        let mut finished: Vec<bool> = vec![false; self.flows.len()];
        // Instant completions.
        for (i, f) in self.flows.iter().enumerate() {
            if f.bytes == 0.0 || f.src == f.dst {
                finished[i] = true;
                done.push(FlowDone { id: f.id, start: f.start, finish: f.start, bytes: f.bytes });
            }
        }
        let mut now = 0.0f64;
        loop {
            let active: Vec<usize> = self
                .flows
                .iter()
                .enumerate()
                .filter(|(i, f)| !finished[*i] && f.start <= now + 1e-12)
                .map(|(i, _)| i)
                .collect();
            let next_arrival = self
                .flows
                .iter()
                .enumerate()
                .filter(|(i, f)| !finished[*i] && f.start > now + 1e-12)
                .map(|(_, f)| f.start)
                .fold(f64::INFINITY, f64::min);
            if active.is_empty() {
                if next_arrival.is_infinite() {
                    break;
                }
                now = next_arrival;
                continue;
            }
            let rates = self.rates(&active);
            // Time to the first completion among active flows.
            let mut dt = f64::INFINITY;
            for (ai, &fi) in active.iter().enumerate() {
                if rates[ai] > 0.0 {
                    dt = dt.min(remaining[fi] / rates[ai]);
                }
            }
            assert!(dt.is_finite(), "deadlock: active flows with zero rate");
            let step = dt.min(next_arrival - now);
            for (ai, &fi) in active.iter().enumerate() {
                remaining[fi] -= rates[ai] * step;
                if remaining[fi] <= 1e-6 {
                    finished[fi] = true;
                    let f = &self.flows[fi];
                    done.push(FlowDone {
                        id: f.id,
                        start: f.start,
                        finish: now + step,
                        bytes: f.bytes,
                    });
                }
            }
            now += step;
        }
        done.sort_by_key(|d| d.id);
        done
    }

    /// Makespan of a flow set: max finish time.
    pub fn run_makespan(&mut self) -> f64 {
        self.run().iter().map(|d| d.finish).fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn single_flow_gets_line_rate() {
        // 100 Gbps host links: 12.5 GB/s; 12.5 GB flow takes 1 s.
        let mut sim = Simulation::new(Topology::flat(4, 100.0));
        sim.add_flow(0, 1, 12.5e9, 0.0);
        let d = sim.run();
        assert!(close(d[0].finish, 1.0, 1e-6));
        assert!(close(d[0].gbps(), 100.0, 0.01));
    }

    #[test]
    fn two_flows_share_receiver_fairly() {
        // Both flows target node 2: its down-link halves each rate.
        let mut sim = Simulation::new(Topology::flat(4, 100.0));
        sim.add_flow(0, 2, 12.5e9, 0.0);
        sim.add_flow(1, 2, 12.5e9, 0.0);
        let d = sim.run();
        assert!(close(d[0].finish, 2.0, 1e-6));
        assert!(close(d[1].finish, 2.0, 1e-6));
    }

    #[test]
    fn disjoint_flows_do_not_interfere() {
        let mut sim = Simulation::new(Topology::flat(4, 100.0));
        sim.add_flow(0, 1, 12.5e9, 0.0);
        sim.add_flow(2, 3, 12.5e9, 0.0);
        let d = sim.run();
        assert!(close(d[0].finish, 1.0, 1e-6));
        assert!(close(d[1].finish, 1.0, 1e-6));
    }

    #[test]
    fn short_flow_releases_bandwidth() {
        // Flow B is half the size; after it finishes, A speeds up.
        // Shared receiver: each at 6.25 GB/s. B (6.25 GB) done at t=1.
        // A then runs at 12.5 GB/s for its remaining 6.25 GB → done t=1.5.
        let mut sim = Simulation::new(Topology::flat(4, 100.0));
        sim.add_flow(0, 2, 12.5e9, 0.0);
        sim.add_flow(1, 2, 6.25e9, 0.0);
        let d = sim.run();
        assert!(close(d[1].finish, 1.0, 1e-6));
        assert!(close(d[0].finish, 1.5, 1e-6));
    }

    #[test]
    fn oversubscribed_tor_throttles_cross_rack() {
        // 2 racks × 4 nodes, 100 Gbps hosts, 200 Gbps uplink → 2:1 oversub.
        let topo = Topology::new(2, 4, 100.0, 200.0);
        assert!(close(topo.oversubscription(), 2.0, 1e-12));
        let mut sim = Simulation::new(topo);
        // All 4 nodes of rack 0 send cross-rack: 400 Gbps demand on a
        // 200 Gbps uplink → each achieves 50 Gbps.
        for i in 0..4 {
            sim.add_flow(i, 4 + i, 6.25e9, 0.0); // 6.25 GB at 6.25 GB/s-half
        }
        let d = sim.run();
        for f in &d {
            assert!(close(f.gbps(), 50.0, 0.5), "gbps={}", f.gbps());
        }
    }

    #[test]
    fn intra_rack_unaffected_by_oversubscription() {
        let topo = Topology::new(2, 4, 100.0, 100.0);
        let mut sim = Simulation::new(topo);
        sim.add_flow(0, 1, 12.5e9, 0.0); // same rack
        let d = sim.run();
        assert!(close(d[0].gbps(), 100.0, 0.1));
    }

    #[test]
    fn staggered_arrivals() {
        // Second flow arrives at t=0.5 sharing the same receiver.
        let mut sim = Simulation::new(Topology::flat(4, 100.0));
        sim.add_flow(0, 2, 12.5e9, 0.0);
        sim.add_flow(1, 2, 12.5e9, 0.5);
        let d = sim.run();
        // A alone for 0.5s (6.25 GB done), then shared: each 6.25 GB/s.
        // A needs 1 more second → t=1.5. B: 12.5 GB at 6.25 GB/s, then
        // alone after A finishes: 6.25 GB done by 1.5, remaining 6.25 GB
        // at full rate → t=2.0.
        assert!(close(d[0].finish, 1.5, 1e-6));
        assert!(close(d[1].finish, 2.0, 1e-6));
    }

    #[test]
    fn zero_bytes_and_loopback_complete_instantly() {
        let mut sim = Simulation::new(Topology::flat(2, 100.0));
        sim.add_flow(0, 1, 0.0, 3.0);
        sim.add_flow(1, 1, 5e9, 2.0);
        let d = sim.run();
        assert!(close(d[0].finish, 3.0, 1e-12));
        assert!(close(d[1].finish, 2.0, 1e-12));
    }

    #[test]
    fn phi_scaling_increases_aggregate_bandwidth() {
        // The Lovelock argument: 1 server with 100 Gbps vs φ=2 NICs with
        // 200 Gbps each. Same total shuffle bytes split across nodes →
        // makespan shrinks by 4x.
        let total_bytes = 100e9;
        // Server-centric: 2 servers exchange.
        let mut s1 = Simulation::new(Topology::flat(2, 100.0));
        s1.add_flow(0, 1, total_bytes / 2.0, 0.0);
        s1.add_flow(1, 0, total_bytes / 2.0, 0.0);
        let m1 = s1.run_makespan();
        // Lovelock φ=2, 200 Gbps/NIC: 4 nodes, pairwise exchange.
        let mut s2 = Simulation::new(Topology::flat(4, 200.0));
        for i in 0..4usize {
            let j = (i + 2) % 4;
            s2.add_flow(i, j, total_bytes / 4.0, 0.0);
        }
        let m2 = s2.run_makespan();
        assert!(close(m1 / m2, 4.0, 0.05), "ratio={}", m1 / m2);
    }

    #[test]
    fn makespan_of_empty_is_zero() {
        let mut sim = Simulation::new(Topology::flat(2, 100.0));
        assert_eq!(sim.run_makespan(), 0.0);
    }
}

//! Memory-bandwidth contention model — the engine behind Figure 3.
//!
//! The paper's Figure 3 runs one independent TPC-H query per hardware
//! thread and observes that per-core performance collapses on x86 hosts
//! (39–88% drop) but only mildly degrades on the IPU E2000 (8–26% drop),
//! because the E2000 has far more DRAM bandwidth per core and no SMT.
//!
//! We reproduce that with a roofline-style model. A workload is summarized
//! by its **demand profile** measured on the real analytics engine
//! ([`WorkloadProfile`]): CPU seconds per query at E2000 single-core speed
//! and bytes of DRAM traffic per query. Running `k` identical instances on
//! platform `P`:
//!
//! * CPU-side rate per thread: `st_speed × smt(P, k) × llc(P, k)` queries/s
//!   (normalized to E2000 1-core = `1/t_cpu`).
//! * Memory-side rate per thread: `(BW_dram(P)/k) / bytes_per_query`.
//! * Achieved rate = min of the two; contention overhead adds a small
//!   super-linear penalty near saturation (queueing in the memory
//!   controller), calibrated so the E2000 lands in the paper's 8–26% band.
//!
//! The model is intentionally simple — the paper's claim is about *which
//! platform degrades and by roughly how much*, which is a pure
//! bandwidth-per-core argument.

use crate::platform::Platform;

/// Demand profile of one query (or any workload unit), measured by the
/// analytics engine on this machine and normalized to E2000 units.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadProfile {
    /// CPU seconds for one execution on a single uncontended E2000 core.
    pub cpu_secs: f64,
    /// DRAM bytes moved per execution (reads + writes, post-LLC).
    pub dram_bytes: f64,
    /// Resident working set in bytes (hash tables + hot columns); drives
    /// the LLC-fit correction.
    pub working_set_bytes: f64,
}

impl WorkloadProfile {
    /// Demanded DRAM bandwidth (bytes/s) of one instance on one
    /// uncontended core of `p`.
    pub fn demand_bps(&self, p: &Platform) -> f64 {
        self.dram_bytes / (self.cpu_secs / p.st_speed)
    }

    /// Operational intensity proxy: bytes per cpu-second (E2000 scale).
    pub fn intensity(&self) -> f64 {
        self.dram_bytes / self.cpu_secs
    }
}

/// Result of simulating `k` concurrent instances on a platform.
#[derive(Clone, Copy, Debug)]
pub struct ContentionResult {
    /// Per-thread rate, queries/sec.
    pub per_core_rate: f64,
    /// Whole-system rate, queries/sec (`k × per_core_rate`).
    pub system_rate: f64,
    /// Fraction of per-thread performance lost vs. one uncontended thread.
    pub slowdown_frac: f64,
    /// True if the memory side (not CPU) is the binding constraint.
    pub memory_bound: bool,
}

/// Fraction of single-thread speed retained per SMT thread when `k`
/// threads run on `cores` physical cores.
fn smt_factor(p: &Platform, k: u32) -> f64 {
    let cores = p.cores();
    if k <= cores {
        1.0
    } else {
        // Fraction of threads whose sibling is busy.
        let shared = (k - cores) as f64 * 2.0 / k as f64;
        1.0 - shared * (1.0 - p.smt_efficiency)
    }
}

/// LLC-fit correction: when the aggregate working set no longer fits in
/// LLC, DRAM traffic is amplified; when it fits, some profiled DRAM
/// traffic never leaves the cache. Returns a multiplier on `dram_bytes`.
fn llc_amplification(p: &Platform, w: &WorkloadProfile, k: u32) -> f64 {
    let llc = p.llc_mib * 1024.0 * 1024.0;
    let per_thread_llc = llc / k as f64;
    let fit = per_thread_llc / w.working_set_bytes.max(1.0);
    if fit >= 1.0 {
        // Working set cached: a fraction of profiled traffic is absorbed.
        0.6
    } else {
        // Partially cached: amplification grows as share shrinks, capped.
        (1.0 / fit.max(0.25)).min(1.6).max(0.6)
    }
}

/// Memory-controller queueing penalty near saturation: at utilization u of
/// the DRAM bus, effective bandwidth is scaled by `1/(1 + beta·u²)`.
fn saturation_penalty(util: f64) -> f64 {
    let u = util.clamp(0.0, 1.0);
    1.0 / (1.0 + 0.30 * u * u)
}

/// CPU-side sharing penalty: even when a core is not bandwidth-starved,
/// co-running neighbours cost it LLC hit rate, memory-controller queueing
/// on its misses, and uncore arbitration. Modeled as
/// `1 / (1 + busy·(BASE + COUPLE·util))` where `busy = (k-1)/k`.
/// Calibrated so an E2000 at full occupancy lands in the paper's 8–26%
/// degradation band across the Fig. 3 query mix.
fn sharing_penalty(k: u32, util: f64) -> f64 {
    const BASE: f64 = 0.08;
    const COUPLE: f64 = 0.35;
    let busy = (k.saturating_sub(1)) as f64 / k as f64;
    1.0 / (1.0 + busy * (BASE + COUPLE * util.clamp(0.0, 1.0)))
}

/// Simulate `k` identical independent instances of `w` on platform `p`.
pub fn simulate(p: &Platform, w: &WorkloadProfile, k: u32) -> ContentionResult {
    assert!(k >= 1 && k <= p.vcpus, "k={k} exceeds vcpus of {}", p.name);
    let base_rate = p.st_speed / w.cpu_secs; // queries/s, uncontended core
    let single = {
        // k = 1 still pays LLC absorption (profile is post-LLC already on
        // an uncontended machine) — use factor at k=1 for consistency.
        let amp = llc_amplification(p, w, 1);
        let mem_rate = p.dram_gbs() * 1e9 / (w.dram_bytes * amp);
        base_rate.min(mem_rate)
    };

    let amp = llc_amplification(p, w, k);
    let bytes_eff = w.dram_bytes * amp;
    // Raw demand if CPU-bound everywhere:
    let raw_cpu_rate = base_rate * smt_factor(p, k);
    let demand = raw_cpu_rate * bytes_eff * k as f64;
    let supply = p.dram_gbs() * 1e9;
    let util = (demand / supply).min(1.0);
    let cpu_rate = raw_cpu_rate * sharing_penalty(k, util);
    let eff_supply = supply * saturation_penalty(util);
    let mem_rate = eff_supply / (k as f64 * bytes_eff);
    let rate = cpu_rate.min(mem_rate);
    ContentionResult {
        per_core_rate: rate,
        system_rate: rate * k as f64,
        slowdown_frac: (1.0 - rate / single).max(0.0),
        memory_bound: mem_rate < cpu_rate,
    }
}

/// Convenience: slowdown at full occupancy (all vCPUs busy).
pub fn full_occupancy(p: &Platform, w: &WorkloadProfile) -> ContentionResult {
    simulate(p, w, p.vcpus)
}

/// Whole-system performance of platform `a` relative to platform `b`, both
/// at full occupancy, for workload `w` (the paper's "Milan shows 1.9-9.2x
/// performance of E2000" quantity).
pub fn system_ratio(a: &Platform, b: &Platform, w: &WorkloadProfile) -> f64 {
    full_occupancy(a, w).system_rate / full_occupancy(b, w).system_rate
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{ipu_e2000, n2d_milan, skylake_fig3};

    /// A memory-light profile (Q6-like compute-bound scan).
    fn light() -> WorkloadProfile {
        WorkloadProfile {
            cpu_secs: 1.0,
            dram_bytes: 2.0e9,
            working_set_bytes: 8.0e6,
        }
    }

    /// A memory-heavy profile (join/agg query with big hash tables).
    fn heavy() -> WorkloadProfile {
        WorkloadProfile {
            cpu_secs: 1.0,
            dram_bytes: 4.0e9,
            working_set_bytes: 64.0e6,
        }
    }

    #[test]
    fn single_core_unaffected() {
        let p = ipu_e2000();
        let r = simulate(&p, &light(), 1);
        assert!(r.slowdown_frac.abs() < 1e-9);
    }

    #[test]
    fn e2000_degrades_mildly() {
        // Paper: E2000 per-core perf drops 8-26% at full occupancy.
        let p = ipu_e2000();
        for w in [light(), heavy()] {
            let r = full_occupancy(&p, &w);
            assert!(
                r.slowdown_frac < 0.35,
                "E2000 slowdown {:.2} too large for {w:?}",
                r.slowdown_frac
            );
        }
    }

    #[test]
    fn x86_degrades_heavily_on_memory_heavy() {
        // Paper: x86 per-core perf drops 39-88%.
        for p in [n2d_milan(), skylake_fig3()] {
            let r = full_occupancy(&p, &heavy());
            assert!(
                r.slowdown_frac > 0.39,
                "{} slowdown {:.2} too small",
                p.name,
                r.slowdown_frac
            );
            assert!(r.memory_bound, "{} should be memory bound", p.name);
        }
    }

    #[test]
    fn x86_degrades_more_than_nic() {
        for w in [light(), heavy()] {
            let nic = full_occupancy(&ipu_e2000(), &w).slowdown_frac;
            let milan = full_occupancy(&n2d_milan(), &w).slowdown_frac;
            assert!(milan > nic, "milan {milan:.2} <= nic {nic:.2} for {w:?}");
        }
    }

    #[test]
    fn system_ratio_in_paper_band() {
        // Paper: Milan whole-system = 1.9-9.2x of E2000 (median 4.7),
        // Skylake 2.1-4.5x (median 3.6). Our profiles should land inside
        // a generous envelope of those bands.
        let e = ipu_e2000();
        for w in [light(), heavy()] {
            let rm = system_ratio(&n2d_milan(), &e, &w);
            assert!(rm > 1.5 && rm < 10.0, "milan ratio {rm}");
            let rs = system_ratio(&skylake_fig3(), &e, &w);
            assert!(rs > 1.5 && rs < 6.0, "skylake ratio {rs}");
        }
    }

    #[test]
    fn monotone_in_k() {
        // Per-core rate must be non-increasing in k.
        let p = n2d_milan();
        let w = heavy();
        let mut last = f64::INFINITY;
        for k in [1, 2, 4, 8, 16, 32, 64, 128, 224] {
            let r = simulate(&p, &w, k);
            assert!(
                r.per_core_rate <= last + 1e-9,
                "rate increased at k={k}"
            );
            last = r.per_core_rate;
        }
    }

    #[test]
    fn smt_factor_bounds() {
        let p = n2d_milan();
        assert!((smt_factor(&p, 1) - 1.0).abs() < 1e-12);
        assert!((smt_factor(&p, p.cores()) - 1.0).abs() < 1e-12);
        let full = smt_factor(&p, p.vcpus);
        assert!((full - p.smt_efficiency).abs() < 1e-9);
        let nic = ipu_e2000();
        assert!((smt_factor(&nic, 16) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn k_beyond_vcpus_panics() {
        simulate(&ipu_e2000(), &light(), 17);
    }
}

//! Distributed-training coordination — §5.3 "Ability to Drive
//! Accelerators" and Table 2.
//!
//! Three pieces:
//!
//! * [`hostmodel`] — the analytic host-resource model behind Table 2:
//!   given a GLaM-style model size, accelerator fleet, and checkpoint
//!   policy, derive host CPU% (normalized to an IPU E2000) and host DRAM
//!   mean/peak over a training run;
//! * [`allreduce`] — ring all-reduce traffic accounting, including the §6
//!   observation that splitting a host's GPUs across φ smart NICs
//!   multiplies datacenter all-reduce traffic by φ;
//! * `driver` (behind the `xla` feature) — the *real* training loop:
//!   loads the AOT-compiled JAX train step
//!   (`artifacts/train_step.hlo.txt`) through the PJRT runtime and steps
//!   it while accounting host-side work exactly like the analytic model
//!   (the E2E example uses this).

pub mod allreduce;
#[cfg(feature = "xla")]
pub mod driver;
pub mod hostmodel;

pub use allreduce::{lovelock_traffic_multiplier, AllReduceTopology};
pub use hostmodel::{CheckpointPolicy, GlamModel, HostUsage, TrainSetup};

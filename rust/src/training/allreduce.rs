//! All-reduce traffic accounting — the §6 "Scaling networking bandwidth"
//! discussion.
//!
//! In a traditional cluster, the GPUs inside one host reduce gradients
//! over NVLink before touching the datacenter network; only one
//! host-level shard crosses the fabric. If Lovelock splits a host's GPUs
//! across φ smart NICs, intra-host reduction shrinks and datacenter
//! all-reduce traffic grows ≈ φ× — the cost the paper flags for workloads
//! with fast intra-host interconnects.

/// Topology of one ring all-reduce over `nodes` network endpoints, each
/// aggregating `gpus_per_node` GPUs locally first.
#[derive(Clone, Copy, Debug)]
pub struct AllReduceTopology {
    pub nodes: u32,
    pub gpus_per_node: u32,
}

impl AllReduceTopology {
    /// Bytes each network endpoint sends over the fabric for one ring
    /// all-reduce of a `bytes`-sized gradient: 2·(n−1)/n · bytes.
    pub fn fabric_bytes_per_node(&self, bytes: f64) -> f64 {
        let n = self.nodes as f64;
        if n <= 1.0 {
            return 0.0;
        }
        2.0 * (n - 1.0) / n * bytes
    }

    /// Total bytes crossing the datacenter fabric.
    pub fn fabric_bytes_total(&self, bytes: f64) -> f64 {
        self.fabric_bytes_per_node(bytes) * self.nodes as f64
    }

    /// Ring all-reduce wall time given per-endpoint NIC bandwidth (GB/s),
    /// ignoring latency terms (bandwidth-dominated regime).
    pub fn time_secs(&self, bytes: f64, nic_gbs: f64) -> f64 {
        self.fabric_bytes_per_node(bytes) / (nic_gbs * 1e9)
    }
}

/// Traffic multiplier of Lovelock vs a traditional cluster: `hosts`
/// servers with `gpus_per_host` GPUs each, vs `hosts × phi` NICs with
/// `gpus_per_host / phi` GPUs each, all-reducing the same gradient.
pub fn lovelock_traffic_multiplier(hosts: u32, gpus_per_host: u32, phi: u32) -> f64 {
    assert!(phi >= 1 && gpus_per_host % phi == 0);
    let grad = 1.0; // normalized gradient size
    let trad = AllReduceTopology { nodes: hosts, gpus_per_node: gpus_per_host }
        .fabric_bytes_total(grad);
    let love = AllReduceTopology {
        nodes: hosts * phi,
        gpus_per_node: gpus_per_host / phi,
    }
    .fabric_bytes_total(grad);
    love / trad
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn ring_formula() {
        let t = AllReduceTopology { nodes: 4, gpus_per_node: 8 };
        assert!(close(t.fabric_bytes_per_node(1e9), 1.5e9, 1.0));
        assert!(close(t.fabric_bytes_total(1e9), 6e9, 1.0));
        let single = AllReduceTopology { nodes: 1, gpus_per_node: 8 };
        assert_eq!(single.fabric_bytes_per_node(1e9), 0.0);
    }

    /// §6: "the total datacenter network traffic for all-reduce
    /// operations will increase by φ" (asymptotically in node count).
    #[test]
    fn lovelock_multiplies_traffic_by_phi() {
        let m2 = lovelock_traffic_multiplier(64, 8, 2);
        assert!(m2 > 1.9 && m2 <= 2.05, "m2={m2}");
        let m4 = lovelock_traffic_multiplier(64, 8, 4);
        assert!(m4 > 3.8 && m4 <= 4.10, "m4={m4}");
    }

    #[test]
    fn time_accounts_for_nic_speed() {
        // φ=2 E2000s (200G) vs one server NIC (100G): per-node traffic is
        // about the same (2·(n-1)/n saturates), but each node has its own
        // faster port, so the all-reduce *time* still improves.
        let grad = 10e9;
        let trad = AllReduceTopology { nodes: 8, gpus_per_node: 8 };
        let love = AllReduceTopology { nodes: 16, gpus_per_node: 4 };
        let t_trad = trad.time_secs(grad, 100.0 / 8.0);
        let t_love = love.time_secs(grad, 200.0 / 8.0);
        assert!(t_love < t_trad, "lovelock {t_love} vs trad {t_trad}");
    }

    #[test]
    #[should_panic]
    fn indivisible_gpu_split_panics() {
        lovelock_traffic_multiplier(8, 6, 4);
    }
}

//! Host-resource model for accelerator-hosted training — Table 2.
//!
//! The paper measures 8 hosts × 4 accelerators (≈50 TFLOPs each) training
//! GLaM-configuration dense models of 1B–39B parameters, global batch 64,
//! and reports per-host CPU% (normalized to an IPU E2000's CPU capacity)
//! and DRAM use. The host does three things: dispatch work to
//! accelerators, move data (input batches + collectives), and checkpoint.
//!
//! Model (DESIGN.md §6): per step the host spends
//! `dispatch_ops × t_dispatch + bytes_moved / host_copy_bw` CPU-seconds;
//! step wall time is `flops_per_step / fleet_flops`. CPU% is the ratio,
//! normalized to the E2000's 16 cores. Host DRAM = runtime baseline +
//! input/staging buffers + (during checkpoint) the host-resident copy of
//! the shard being written — 2× the shard for a monolithic snapshot,
//! shard + chunk for the paper's proposed *chunked streaming* policy.

/// A GLaM-style dense model configuration.
#[derive(Clone, Copy, Debug)]
pub struct GlamModel {
    pub name: &'static str,
    pub params: f64,
    /// Bytes per parameter held on accelerators (weights + optimizer
    /// slots as trained; bf16 weights + f32 Adam moments ≈ 10 B, of which
    /// the *checkpointed* state is params × ckpt_bytes_per_param).
    pub ckpt_bytes_per_param: f64,
}

impl GlamModel {
    pub fn glam_1b() -> Self {
        Self { name: "GLaM1B", params: 1.0e9, ckpt_bytes_per_param: 6.4 }
    }
    pub fn glam_4b() -> Self {
        Self { name: "GLaM4B", params: 4.0e9, ckpt_bytes_per_param: 3.6 }
    }
    pub fn glam_17b() -> Self {
        Self { name: "GLaM17B", params: 17.0e9, ckpt_bytes_per_param: 3.8 }
    }
    pub fn glam_39b() -> Self {
        Self { name: "GLaM39B", params: 39.0e9, ckpt_bytes_per_param: 3.7 }
    }

    pub fn table2_models() -> Vec<Self> {
        vec![Self::glam_1b(), Self::glam_4b(), Self::glam_17b(), Self::glam_39b()]
    }
}

/// Checkpoint policy: how a host writes its shard of the snapshot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CheckpointPolicy {
    /// Materialize the full host shard in host DRAM, then write.
    Monolithic,
    /// The paper's §5.3 proposal: stream the shard in `chunk_bytes`
    /// pieces, capping the host-DRAM spike.
    ChunkedStream { chunk_bytes: u64 },
}

/// Training fleet setup (defaults = the paper's Table 2 experiment).
#[derive(Clone, Copy, Debug)]
pub struct TrainSetup {
    pub hosts: u32,
    pub accels_per_host: u32,
    /// Per-accelerator throughput, FLOP/s (paper: "about 50 TFLOPs").
    pub accel_flops: f64,
    pub global_batch: u32,
    pub seq_len: u32,
    pub steps: u32,
    /// Steps between checkpoints.
    pub ckpt_every: u32,
    pub policy: CheckpointPolicy,
}

impl Default for TrainSetup {
    fn default() -> Self {
        Self {
            hosts: 8,
            accels_per_host: 4,
            accel_flops: 50e12,
            global_batch: 64,
            seq_len: 1024,
            steps: 1000,
            ckpt_every: 250,
            policy: CheckpointPolicy::Monolithic,
        }
    }
}

/// Table 2 row: derived host resource usage.
#[derive(Clone, Copy, Debug)]
pub struct HostUsage {
    /// Mean / peak host CPU utilization, normalized to one E2000 (1.0 =
    /// all 16 ARM cores busy).
    pub mean_cpu_frac: f64,
    pub peak_cpu_frac: f64,
    /// Checkpointed state per accelerator / per host, bytes.
    pub state_per_accel: f64,
    pub state_per_host: f64,
    /// Mean / max host DRAM bytes.
    pub mean_mem: f64,
    pub max_mem: f64,
    /// Step wall time, seconds.
    pub step_secs: f64,
}

/// Host-side modeling constants (calibrated in DESIGN.md §6; these are
/// the knobs, not spec data).
const HOST_BASE_MEM: f64 = 3.35e9; // runtime + framework buffers
const HOST_MEM_PER_SHARD: f64 = 0.067; // staging growth per shard byte
/// Steady host work per step, E2000-core-seconds:
/// `COEF · (params/1e9)^EXP` — dispatch, input pipeline, and collective
/// staging grow sub-linearly with model size (calibrated to Table 2's
/// mean CPU column: 4.8% at 1B falling to 2.1% at 39B).
const HOST_WORK_COEF: f64 = 0.19;
const HOST_WORK_EXP: f64 = 0.8;
const E2000_CORES: f64 = 16.0;
/// Checkpoint serialization rate per E2000-core-second, bytes.
const CKPT_BYTES_PER_CORE_SEC: f64 = 2.0e9;
/// Wall window a checkpoint burst is smeared over in the peak-CPU sample
/// (the paper's monitor samples coarsely; 4 s reproduces the peak column).
const CKPT_PEAK_WINDOW_SECS: f64 = 4.0;

impl TrainSetup {
    pub fn total_accels(&self) -> u32 {
        self.hosts * self.accels_per_host
    }

    /// FLOPs per training step (dense transformer ≈ 6 · params · tokens).
    pub fn flops_per_step(&self, m: &GlamModel) -> f64 {
        6.0 * m.params * (self.global_batch as f64 * self.seq_len as f64)
    }

    pub fn step_secs(&self, m: &GlamModel) -> f64 {
        self.flops_per_step(m) / (self.accel_flops * self.total_accels() as f64)
    }

    /// Derive the Table 2 row for model `m`.
    pub fn host_usage(&self, m: &GlamModel) -> HostUsage {
        let step = self.step_secs(m);
        let state_total = m.params * m.ckpt_bytes_per_param;
        let state_per_accel = state_total / self.total_accels() as f64;
        let state_per_host = state_per_accel * self.accels_per_host as f64;

        // Steady-state host CPU per step: dispatch + input pipeline +
        // collective staging (sub-linear in model size).
        let steady_cpu_secs = HOST_WORK_COEF * (m.params / 1e9).powf(HOST_WORK_EXP);
        let mean_steady = steady_cpu_secs / step / E2000_CORES;

        // Checkpoint burst: serialize the host shard.
        let ckpt_cpu_secs = state_per_host / CKPT_BYTES_PER_CORE_SEC;
        let ckpt_window_secs = step * self.ckpt_every as f64;
        let ckpt_mean_contrib = ckpt_cpu_secs / ckpt_window_secs / E2000_CORES;
        // Peak: the burst as seen by a coarse sampler.
        let peak_cpu = mean_steady + ckpt_cpu_secs / (CKPT_PEAK_WINDOW_SECS * E2000_CORES);

        // Memory.
        let mean_mem = HOST_BASE_MEM + HOST_MEM_PER_SHARD * state_per_host;
        let ckpt_extra = match self.policy {
            CheckpointPolicy::Monolithic => state_per_host,
            CheckpointPolicy::ChunkedStream { chunk_bytes } => {
                (2.0 * chunk_bytes as f64).min(state_per_host)
            }
        };
        // Monolithic peak ≈ mean + staging copy of the shard (+ the
        // serialization double-buffer ≈ 0.7× shard, matching the paper's
        // "up to twice the model size" at the host level).
        let max_mem = mean_mem
            + ckpt_extra
            + match self.policy {
                CheckpointPolicy::Monolithic => 0.7 * state_per_host,
                CheckpointPolicy::ChunkedStream { .. } => 0.0,
            };

        HostUsage {
            mean_cpu_frac: mean_steady + ckpt_mean_contrib,
            peak_cpu_frac: peak_cpu,
            state_per_accel,
            state_per_host,
            mean_mem,
            max_mem,
            step_secs: step,
        }
    }

    /// §5.3: how many accelerators can one E2000 (48 GB) drive for this
    /// model under the given checkpoint policy?
    pub fn accels_per_e2000(&self, m: &GlamModel, dram_bytes: f64) -> u32 {
        let mut best = 0;
        for k in 1..=8u32 {
            let setup = TrainSetup { accels_per_host: k, ..*self };
            let u = setup.host_usage(m);
            if u.max_mem <= dram_bytes && u.peak_cpu_frac <= 1.0 {
                best = k;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gb(x: f64) -> f64 {
        x * 1e9
    }

    #[test]
    fn table2_cpu_bands() {
        // Paper: mean CPU 2.1%–4.8% (decreasing with model size), peak
        // 6.2%–13.3% (increasing with model size).
        let s = TrainSetup::default();
        let models = GlamModel::table2_models();
        let rows: Vec<HostUsage> = models.iter().map(|m| s.host_usage(m)).collect();
        for (m, r) in models.iter().zip(&rows) {
            assert!(
                r.mean_cpu_frac > 0.01 && r.mean_cpu_frac < 0.09,
                "{}: mean {:.3}",
                m.name,
                r.mean_cpu_frac
            );
            assert!(
                r.peak_cpu_frac > r.mean_cpu_frac && r.peak_cpu_frac < 0.20,
                "{}: peak {:.3}",
                m.name,
                r.peak_cpu_frac
            );
        }
        // Trends.
        assert!(rows[0].mean_cpu_frac > rows[3].mean_cpu_frac, "mean should fall with size");
        assert!(rows[3].peak_cpu_frac > rows[0].peak_cpu_frac, "peak should rise with size");
    }

    #[test]
    fn table2_memory_bands() {
        // Paper: mean 3.4–4.7 GB; max 5.0–35.7 GB.
        let s = TrainSetup::default();
        let rows: Vec<HostUsage> =
            GlamModel::table2_models().iter().map(|m| s.host_usage(m)).collect();
        assert!(rows[0].mean_mem > gb(3.0) && rows[0].mean_mem < gb(3.8));
        assert!(rows[3].mean_mem > gb(4.2) && rows[3].mean_mem < gb(5.2));
        assert!(rows[0].max_mem > gb(4.0) && rows[0].max_mem < gb(6.0));
        assert!(rows[3].max_mem > gb(30.0) && rows[3].max_mem < gb(42.0));
    }

    #[test]
    fn table2_state_sizes() {
        // Paper: per accel 0.2 / 0.4 / 2.0 / 4.5 GB; per host ×4.
        let s = TrainSetup::default();
        let per_accel: Vec<f64> = GlamModel::table2_models()
            .iter()
            .map(|m| s.host_usage(m).state_per_accel)
            .collect();
        let paper = [0.2e9, 0.4e9, 2.0e9, 4.5e9];
        for (got, want) in per_accel.iter().zip(paper.iter()) {
            let rel = (got - want).abs() / want;
            assert!(rel < 0.15, "state/accel {got:.2e} vs paper {want:.2e}");
        }
        let u = s.host_usage(&GlamModel::glam_39b());
        assert!((u.state_per_host / u.state_per_accel - 4.0).abs() < 1e-9);
    }

    #[test]
    fn peak_cpu_well_under_e2000() {
        // The paper's headline: "Even the peak CPU use is well below the
        // capacity of a smart NIC".
        let s = TrainSetup::default();
        for m in GlamModel::table2_models() {
            assert!(s.host_usage(&m).peak_cpu_frac < 0.5, "{}", m.name);
        }
    }

    #[test]
    fn chunked_checkpoint_caps_peak() {
        let mono = TrainSetup::default();
        let chunked = TrainSetup {
            policy: CheckpointPolicy::ChunkedStream { chunk_bytes: 256 << 20 },
            ..mono
        };
        let m = GlamModel::glam_39b();
        let u_mono = mono.host_usage(&m);
        let u_chunk = chunked.host_usage(&m);
        assert!(u_chunk.max_mem < u_mono.max_mem / 2.0);
        // Chunked 39B fits an E2000's 48 GB with margin; monolithic is
        // marginal (35.7 GB peak of a 48 GB part).
        assert!(u_chunk.max_mem < 8e9);
    }

    #[test]
    fn e2000_drives_2_to_4_accels() {
        // Paper: "each E2000 can drive 2-4 accelerators depending on the
        // model size" (with chunked checkpointing, 48 GB DRAM).
        let s = TrainSetup {
            policy: CheckpointPolicy::ChunkedStream { chunk_bytes: 256 << 20 },
            ..TrainSetup::default()
        };
        let k39 = s.accels_per_e2000(&GlamModel::glam_39b(), 48e9);
        let k1 = s.accels_per_e2000(&GlamModel::glam_1b(), 48e9);
        assert!(k39 >= 2, "39B supports {k39} accels");
        assert!(k1 >= 4, "1B supports {k1} accels");
    }

    #[test]
    fn step_time_scales_with_params() {
        let s = TrainSetup::default();
        let t1 = s.step_secs(&GlamModel::glam_1b());
        let t39 = s.step_secs(&GlamModel::glam_39b());
        assert!((t39 / t1 - 39.0).abs() < 1e-6);
        // 1B, batch 64 × 1024 tokens, 32 × 50 TFLOPs → 0.25 s/step.
        assert!((t1 - 0.2458).abs() < 0.01, "t1={t1}");
    }

    #[test]
    fn more_hosts_lower_per_host_burden() {
        let base = TrainSetup::default();
        let bigger = TrainSetup { hosts: 16, ..base };
        let m = GlamModel::glam_17b();
        assert!(bigger.host_usage(&m).state_per_host < base.host_usage(&m).state_per_host);
    }
}

//! The real training loop: AOT-compiled JAX train step driven from Rust.
//!
//! Artifact contract with `python/compile/aot.py` (see manifest.toml in
//! the artifacts directory):
//!
//! * `init_<name>.hlo.txt`  — `(seed i32[1]) -> f32[N+1]` packed state
//!   (slot 0 = last loss, slots 1.. = params ‖ adam-m ‖ adam-v ‖ step).
//! * `train_step_<name>.hlo.txt` — `(state f32[N+1], tokens i32[B,S+1])
//!   -> f32[N+1]` one AdamW step of next-token LM loss.
//!
//! The state never leaves the device between steps (buffer-to-buffer
//! execution); the loss is read back only at logging intervals. This is
//! the "CPU as coordinator" workload of §5.3: the Rust host does exactly
//! what the paper says hosts do — dispatch steps, feed batches, and
//! checkpoint — and the driver accounts that host work the same way the
//! analytic Table 2 model does.

use crate::configfmt::parse_toml;
use crate::prng::Pcg64;
use crate::runtime::{artifact_path, literal_i32, to_f32, Engine, Module};
use crate::error::{Context, Result};
use std::time::Instant;
use xla::PjRtBuffer;

/// Parsed manifest entry for one model artifact pair.
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    /// Packed state length *including* the loss slot.
    pub state_len: usize,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub params: usize,
}

/// Read `artifacts/manifest.toml` and return the spec for `name`.
pub fn load_spec(name: &str) -> Result<ModelSpec> {
    let path = artifact_path("manifest.toml");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    let cfg = parse_toml(&text).map_err(crate::error::Error::msg)?;
    let key = |k: &str| format!("{name}.{k}");
    let get = |k: &str| -> Result<i64> {
        cfg.get(&key(k))
            .and_then(|v| v.as_i64())
            .with_context(|| format!("manifest missing {}", key(k)))
    };
    Ok(ModelSpec {
        name: name.to_string(),
        state_len: get("state_len")? as usize,
        batch: get("batch")? as usize,
        seq: get("seq")? as usize,
        vocab: get("vocab")? as usize,
        params: get("params")? as usize,
    })
}

/// Synthetic-corpus sampler: Zipf unigrams + a deterministic bigram rule
/// (`next = (3·prev + 7) mod vocab` with prob. 0.5). The mixture gives
/// the model real structure to learn, so the loss curve falls visibly
/// below the unigram entropy.
pub struct CorpusGen {
    rng: Pcg64,
    vocab: u32,
}

impl CorpusGen {
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self { rng: Pcg64::seed_from_u64(seed), vocab: vocab as u32 }
    }

    /// One batch of token ids, shape `[batch, seq + 1]` flattened.
    pub fn batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(batch * (seq + 1));
        for _ in 0..batch {
            let mut prev = self.rng.gen_zipf(self.vocab as u64, 1.05) as u32;
            out.push(prev as i32);
            for _ in 0..seq {
                let next = if self.rng.gen_bool(0.5) {
                    (3 * prev + 7) % self.vocab
                } else {
                    self.rng.gen_zipf(self.vocab as u64, 1.05) as u32
                };
                out.push(next as i32);
                prev = next;
            }
        }
        out
    }
}

/// Host-side accounting for one training run (the Table 2 quantities,
/// measured rather than modeled).
#[derive(Clone, Copy, Debug, Default)]
pub struct HostAccounting {
    pub steps: u32,
    /// Wall seconds inside PJRT execute (the "accelerator" time).
    pub device_secs: f64,
    /// Wall seconds of host work (batch gen, upload, bookkeeping).
    pub host_secs: f64,
    /// Bytes uploaded host→device.
    pub h2d_bytes: u64,
    /// Bytes downloaded device→host (loss reads + checkpoints).
    pub d2h_bytes: u64,
}

impl HostAccounting {
    /// Host CPU fraction: host work over total wall time.
    pub fn host_cpu_frac(&self) -> f64 {
        let total = self.device_secs + self.host_secs;
        if total == 0.0 {
            0.0
        } else {
            self.host_secs / total
        }
    }
}

/// The driver: owns the engine, the compiled modules, and device state.
///
/// PJRT's CPU client enqueues executions asynchronously and does not pin
/// input buffers; freeing an input while its computation is in flight
/// corrupts memory. The driver therefore parks consumed inputs in a
/// `graveyard` and only drops them at *sync points* — full-literal reads
/// of the state (which do await completion). The sync interval adapts to
/// the state size so retained memory stays bounded (~256 MiB).
pub struct TrainDriver {
    engine: Engine,
    step_mod: Module,
    pub spec: ModelSpec,
    state: Option<PjRtBuffer>,
    corpus: CorpusGen,
    graveyard: Vec<PjRtBuffer>,
    /// Host literals whose async h2d copies may still be in flight.
    graveyard_lits: Vec<xla::Literal>,
    sync_every: u32,
    pub accounting: HostAccounting,
    pub loss_log: Vec<(u32, f32)>,
    last_loss: f32,
}

impl TrainDriver {
    /// Load artifacts for model `name` ("tiny" or "100m").
    pub fn load(name: &str, data_seed: u64) -> Result<Self> {
        let spec = load_spec(name)?;
        let engine = Engine::cpu()?;
        let step_mod = engine.load_module(artifact_path(&format!("train_step_{name}.hlo.txt")))?;
        let corpus = CorpusGen::new(spec.vocab, data_seed);
        // Bound graveyard memory at ~4 GiB of retained state copies
        // (§Perf L3: syncing every step costs a full-state d2h copy; a
        // deeper retirement window amortizes it).
        let state_bytes = (spec.state_len * 4) as u64;
        let sync_every = ((4u64 << 30) / state_bytes.max(1)).clamp(1, 16) as u32;
        Ok(Self {
            engine,
            step_mod,
            spec,
            state: None,
            corpus,
            graveyard: Vec::new(),
            graveyard_lits: Vec::new(),
            sync_every,
            accounting: HostAccounting::default(),
            loss_log: Vec::new(),
            last_loss: f32::NAN,
        })
    }

    /// Initialize packed state via the init artifact.
    pub fn init(&mut self, seed: i32) -> Result<()> {
        let init_mod = self
            .engine
            .load_module(artifact_path(&format!("init_{}.hlo.txt", self.spec.name)))?;
        let seed_lit = literal_i32(&[seed], &[1])?;
        let mut outs = init_mod.execute(&[seed_lit])?;
        crate::ensure!(!outs.is_empty(), "init produced no outputs");
        let state_lit = outs.swap_remove(0);
        let state = self.engine.to_device(&state_lit)?;
        // The h2d copy is asynchronous: keep the literal alive until the
        // next sync point.
        self.graveyard_lits.push(state_lit);
        self.state = Some(state);
        Ok(())
    }

    /// Run `n` steps, logging loss every `log_every` steps.
    pub fn run(&mut self, n: u32, log_every: u32) -> Result<()> {
        for _ in 0..n {
            self.step()?;
            let s = self.accounting.steps;
            if log_every > 0 && s % log_every == 0 {
                let loss = self.read_loss()?;
                self.loss_log.push((s, loss));
            }
        }
        // Final sync so all enqueued work has retired before returning.
        self.read_loss()?;
        Ok(())
    }

    /// One training step (buffer-to-buffer, asynchronous). The consumed
    /// input buffers go to the graveyard; every `sync_every` steps a full
    /// state read synchronizes and retires them.
    pub fn step(&mut self) -> Result<()> {
        let t_host = Instant::now();
        let tokens = self.corpus.batch(self.spec.batch, self.spec.seq);
        let tok_lit = literal_i32(&tokens, &[self.spec.batch as i64, self.spec.seq as i64 + 1])?;
        let tok_buf = self.engine.to_device(&tok_lit)?;
        self.accounting.h2d_bytes += (tokens.len() * 4) as u64;
        let state = self.state.take().context("driver not initialized")?;
        self.accounting.host_secs += t_host.elapsed().as_secs_f64();

        let t_dev = Instant::now();
        let mut outs = self.step_mod.execute_buffers(&[&state, &tok_buf])?;
        crate::ensure!(!outs.is_empty(), "train step produced no outputs");
        self.graveyard.push(state);
        self.graveyard.push(tok_buf);
        self.graveyard_lits.push(tok_lit);
        self.state = Some(outs.swap_remove(0));
        self.accounting.device_secs += t_dev.elapsed().as_secs_f64();
        self.accounting.steps += 1;
        if self.accounting.steps % self.sync_every == 0 {
            self.read_loss()?; // true sync point; clears the graveyard
        }
        Ok(())
    }

    /// Loss observed at the most recent sync point (NaN before the first).
    pub fn last_loss(&self) -> f32 {
        self.last_loss
    }

    /// Read the loss slot via a full state literal — a genuine
    /// synchronization point, after which the graveyard is retired.
    pub fn read_loss(&mut self) -> Result<f32> {
        let state = self.state.as_ref().context("driver not initialized")?;
        let t_dev = Instant::now();
        let lit = state
            .to_literal_sync()
            .map_err(|e| crate::err!("d2h: {e}"))?;
        self.accounting.device_secs += t_dev.elapsed().as_secs_f64();
        self.accounting.d2h_bytes += (self.spec.state_len * 4) as u64;
        self.graveyard.clear();
        self.graveyard_lits.clear();
        let v = to_f32(&lit)?;
        crate::ensure!(!v.is_empty(), "empty state");
        self.last_loss = v[0];
        Ok(v[0])
    }

    /// Checkpoint the packed state to `path` (raw f32 LE), counting the
    /// d2h bytes like the host model does. With `chunked`, stream in
    /// 16 MiB chunks (the §5.3 proposal) instead of one buffer.
    pub fn checkpoint(&mut self, path: &std::path::Path, chunked: bool) -> Result<u64> {
        use std::io::Write;
        let state = self.state.as_ref().context("driver not initialized")?;
        let lit = state
            .to_literal_sync()
            .map_err(|e| crate::err!("d2h: {e}"))?;
        let v = to_f32(&lit)?;
        self.accounting.d2h_bytes += (v.len() * 4) as u64;
        let mut f = std::fs::File::create(path)?;
        if chunked {
            const CHUNK: usize = 4 << 20; // floats per chunk = 16 MiB
            for c in v.chunks(CHUNK) {
                let bytes: Vec<u8> = c.iter().flat_map(|x| x.to_le_bytes()).collect();
                f.write_all(&bytes)?;
            }
        } else {
            let bytes: Vec<u8> = v.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&bytes)?;
        }
        Ok((v.len() * 4) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine-dependent tests live in rust/tests/integration_runtime.rs;
    // here we cover the host-side pieces.

    #[test]
    fn corpus_tokens_in_range() {
        let mut g = CorpusGen::new(512, 7);
        let batch = g.batch(4, 32);
        assert_eq!(batch.len(), 4 * 33);
        assert!(batch.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn corpus_has_bigram_structure() {
        let mut g = CorpusGen::new(512, 7);
        let batch = g.batch(64, 128);
        // About half of adjacent pairs should follow the bigram rule.
        let mut hits = 0;
        let mut total = 0;
        for row in batch.chunks(129) {
            for w in row.windows(2) {
                total += 1;
                if w[1] == (3 * w[0] + 7) % 512 {
                    hits += 1;
                }
            }
        }
        let frac = hits as f64 / total as f64;
        assert!(frac > 0.4 && frac < 0.6, "bigram frac {frac}");
    }

    #[test]
    fn corpus_deterministic() {
        let a = CorpusGen::new(256, 3).batch(2, 16);
        let b = CorpusGen::new(256, 3).batch(2, 16);
        assert_eq!(a, b);
    }

    #[test]
    fn accounting_fraction() {
        let acc = HostAccounting {
            steps: 10,
            device_secs: 9.0,
            host_secs: 1.0,
            h2d_bytes: 100,
            d2h_bytes: 50,
        };
        assert!((acc.host_cpu_frac() - 0.1).abs() < 1e-12);
        assert_eq!(HostAccounting::default().host_cpu_frac(), 0.0);
    }

    #[test]
    fn load_spec_fails_without_artifacts() {
        std::env::set_var("LOVELOCK_ARTIFACTS", "/nonexistent-artifacts-dir");
        assert!(load_spec("tiny").is_err());
        std::env::remove_var("LOVELOCK_ARTIFACTS");
    }
}

//! Execution substrate: a work-stealing-free but effective thread pool,
//! scoped parallel loops, and a tiny deadline-driven event loop.
//!
//! The offline registry carries neither tokio nor rayon; the Lovelock
//! coordinator needs (a) a pool to run worker-node tasks concurrently,
//! (b) `parallel_for`-style data parallelism for the analytics engine's
//! morsel-parallel operators, and (c) a timer wheel for simulated-time
//! pacing in the examples. This module provides all three on std only.
//!
//! ```
//! use lovelock::exec::parallel_for_chunks;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! // Sum 0..1000 in 64-row morsels on 4 threads.
//! let total = AtomicU64::new(0);
//! parallel_for_chunks(1000, 64, 4, |lo, hi| {
//!     let s: u64 = (lo as u64..hi as u64).sum();
//!     total.fetch_add(s, Ordering::Relaxed);
//! });
//! assert_eq!(total.into_inner(), 499_500);
//! ```

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool with a shared injector queue.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    active: Arc<AtomicUsize>,
    idle_cv: Arc<(Mutex<()>, Condvar)>,
}

impl ThreadPool {
    /// A pool with `n` worker threads (`n == 0` → number of CPUs).
    pub fn new(n: usize) -> Self {
        let n = if n == 0 { num_cpus() } else { n };
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let active = Arc::new(AtomicUsize::new(0));
        let idle_cv = Arc::new((Mutex::new(()), Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let active = Arc::clone(&active);
                let idle_cv = Arc::clone(&idle_cv);
                std::thread::Builder::new()
                    .name(format!("lovelock-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                active.fetch_add(1, Ordering::SeqCst);
                                job();
                                active.fetch_sub(1, Ordering::SeqCst);
                                idle_cv.1.notify_all();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers, active, idle_cv }
    }

    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool shut down");
    }

    /// Submit a job and get a [`JoinHandle`] for its result.
    pub fn submit<T: Send + 'static, F: FnOnce() -> T + Send + 'static>(
        &self,
        f: F,
    ) -> JoinHandle<T> {
        let (tx, rx) = channel();
        self.spawn(move || {
            // Receiver may have been dropped; that's fine.
            let _ = tx.send(f());
        });
        JoinHandle { rx }
    }

    /// Number of jobs currently executing.
    pub fn active(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }

    /// Block until no job is executing (note: queued-but-unstarted jobs
    /// are not covered — pair with result handles for full joins).
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.idle_cv;
        let mut guard = lock.lock().unwrap();
        while self.active() > 0 {
            let (g, _timeout) = cv
                .wait_timeout(guard, std::time::Duration::from_millis(10))
                .unwrap();
            guard = g;
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Handle to a pool-submitted job's result.
pub struct JoinHandle<T> {
    rx: Receiver<T>,
}

impl<T> JoinHandle<T> {
    /// Block for the result. Panics if the job panicked.
    pub fn join(self) -> T {
        self.rx.recv().expect("job panicked or pool shut down")
    }

    /// Non-blocking poll.
    pub fn try_join(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

fn num_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Process `items` with `f` on up to `threads` scoped threads, preserving
/// input order in the output. Panics in `f` propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_with(items, threads, || (), |_, x| f(x))
}

/// [`parallel_map`] with per-thread mutable state: each worker thread
/// calls `init` exactly once and threads the resulting value through
/// every item it processes. This is how the analytics engine reuses its
/// per-task scratch (selection ping-pong buffers, batch columns, group
/// ids) across the morsels one pool thread handles — the state lives for
/// the whole map, so steady-state morsels allocate nothing.
pub fn parallel_map_with<T, R, S, I, F>(items: Vec<T>, threads: usize, init: I, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, T) -> R + Sync,
{
    let threads = if threads == 0 { num_cpus() } else { threads }.max(1);
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    if threads == 1 || n == 1 {
        let mut state = init();
        return items.into_iter().map(|x| f(&mut state, x)).collect();
    }
    let next = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let item = inputs[i].lock().unwrap().take().unwrap();
                    let out = f(&mut state, item);
                    *outputs[i].lock().unwrap() = Some(out);
                }
            });
        }
    });
    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker panicked"))
        .collect()
}

/// Parallel iteration over index ranges in contiguous chunks, collecting
/// each chunk's result **in chunk order** — the morsel-execution
/// primitive of the analytics engine (each chunk is one morsel).
pub fn parallel_map_chunks<R, F>(len: usize, chunk: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Sync,
{
    parallel_map_chunks_with(len, chunk, threads, || (), |_, s, e| f(s, e))
}

/// [`parallel_map_chunks`] with per-thread state (see
/// [`parallel_map_with`]): `f` receives the thread's state plus the
/// chunk bounds.
pub fn parallel_map_chunks_with<R, S, I, F>(
    len: usize,
    chunk: usize,
    threads: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, usize) -> R + Sync,
{
    let chunk = chunk.max(1);
    let ranges: Vec<(usize, usize)> = (0..len)
        .step_by(chunk)
        .map(|s| (s, (s + chunk).min(len)))
        .collect();
    parallel_map_with(ranges, threads, init, |state, (s, e)| f(state, s, e))
}

/// [`parallel_map_chunks`] for side-effect-only bodies.
pub fn parallel_for_chunks<F>(len: usize, chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    parallel_map_chunks(len, chunk, threads, |s, e| f(s, e));
}

/// Selection-vector-aware variant of [`parallel_map_chunks`]: splits a
/// selection vector (row ids surviving a predicate) into contiguous
/// `chunk`-sized slices and maps each on up to `threads` scoped threads,
/// collecting results **in slice order**.
///
/// Where [`parallel_map_chunks`] balances raw row ranges, this balances
/// *surviving* rows: after a selective predicate the survivors may
/// cluster in a few ranges, and slicing the selection spreads the
/// downstream (aggregation/probe) work evenly across threads. The
/// engine's parallel driver uses it for the aggregate phase of every
/// query.
pub fn parallel_map_sel_chunks<R, F>(sel: &[u32], chunk: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(&[u32]) -> R + Sync,
{
    parallel_map_sel_chunks_with(sel, chunk, threads, || (), |_, s| f(s))
}

/// [`parallel_map_sel_chunks`] with per-thread state (see
/// [`parallel_map_with`]) — the engine's aggregation phase uses it to
/// reuse one `TaskScratch` per pool thread across all the selection
/// slices that thread aggregates.
pub fn parallel_map_sel_chunks_with<R, S, I, F>(
    sel: &[u32],
    chunk: usize,
    threads: usize,
    init: I,
    f: F,
) -> Vec<R>
where
    R: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, &[u32]) -> R + Sync,
{
    let chunk = chunk.max(1);
    let slices: Vec<&[u32]> = sel.chunks(chunk).collect();
    parallel_map_with(slices, threads, init, |state, s| f(state, s))
}

/// One scheduled timer entry.
struct TimerEntry {
    deadline: Instant,
    seq: u64,
    job: Job,
}

impl PartialEq for TimerEntry {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for TimerEntry {}
impl PartialOrd for TimerEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for TimerEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap by (deadline, seq) via reversal.
        other
            .deadline
            .cmp(&self.deadline)
            .then(other.seq.cmp(&self.seq))
    }
}

/// A deadline-driven event loop: schedule closures at instants, then run
/// until drained or stopped. Used for paced request injection in examples.
pub struct EventLoop {
    heap: BinaryHeap<TimerEntry>,
    seq: u64,
    stop: Arc<AtomicBool>,
}

impl Default for EventLoop {
    fn default() -> Self {
        Self::new()
    }
}

impl EventLoop {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, stop: Arc::new(AtomicBool::new(false)) }
    }

    pub fn stopper(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn schedule_at<F: FnOnce() + Send + 'static>(&mut self, at: Instant, f: F) {
        self.seq += 1;
        self.heap.push(TimerEntry { deadline: at, seq: self.seq, job: Box::new(f) });
    }

    pub fn schedule_after<F: FnOnce() + Send + 'static>(&mut self, after: Duration, f: F) {
        self.schedule_at(Instant::now() + after, f);
    }

    pub fn pending(&self) -> usize {
        self.heap.len()
    }

    /// Run until all timers fired or the stop flag is set.
    pub fn run(&mut self) {
        while let Some(top) = self.heap.peek() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            let now = Instant::now();
            if top.deadline > now {
                std::thread::sleep((top.deadline - now).min(Duration::from_millis(5)));
                continue;
            }
            let entry = self.heap.pop().unwrap();
            (entry.job)();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_jobs() {
        let pool = ThreadPool::new(4);
        let c = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..100)
            .map(|i| {
                let c = c.clone();
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                    i * 2
                })
            })
            .collect();
        let sum: u64 = handles.into_iter().map(|h| h.join()).sum();
        assert_eq!(sum, (0..100).map(|i| i * 2).sum::<u64>());
        assert_eq!(c.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_returns_after_drain() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let c = c.clone();
                pool.submit(move || {
                    std::thread::sleep(Duration::from_millis(2));
                    c.fetch_add(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in handles {
            h.join();
        }
        pool.wait_idle();
        assert_eq!(pool.active(), 0);
        assert_eq!(c.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn pool_zero_means_ncpus() {
        let pool = ThreadPool::new(0);
        assert!(pool.threads() >= 1);
    }

    #[test]
    fn pool_shutdown_joins() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = c.clone();
            pool.spawn(move || {
                std::thread::sleep(Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must wait for all jobs
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..1000).collect::<Vec<_>>(), 8, |x| x * x);
        assert_eq!(out, (0..1000).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
        assert_eq!(parallel_map(vec![7], 4, |x| x + 1), vec![8]);
    }

    #[test]
    fn parallel_map_chunks_ordered() {
        let out = parallel_map_chunks(10, 3, 4, |s, e| (s, e));
        assert_eq!(out, vec![(0, 3), (3, 6), (6, 9), (9, 10)]);
        let empty: Vec<(usize, usize)> = parallel_map_chunks(0, 3, 4, |s, e| (s, e));
        assert!(empty.is_empty());
    }

    #[test]
    fn parallel_for_chunks_covers_all() {
        let seen = Mutex::new(vec![false; 1003]);
        parallel_for_chunks(1003, 64, 4, |s, e| {
            let mut g = seen.lock().unwrap();
            for i in s..e {
                assert!(!g[i], "index {i} visited twice");
                g[i] = true;
            }
        });
        assert!(seen.into_inner().unwrap().iter().all(|x| *x));
    }

    #[test]
    fn parallel_map_sel_chunks_ordered_and_complete() {
        let sel: Vec<u32> = (0..101).map(|i| i * 3).collect();
        let out = parallel_map_sel_chunks(&sel, 7, 4, |s| s.to_vec());
        assert_eq!(out.concat(), sel, "slice order or content broken");
        assert_eq!(out.len(), 101usize.div_ceil(7));
        for (i, s) in out.iter().enumerate() {
            let want = if i == out.len() - 1 { 101 % 7 } else { 7 };
            assert_eq!(s.len(), if want == 0 { 7 } else { want });
        }
    }

    #[test]
    fn parallel_map_with_state_is_per_thread_and_reused() {
        // Each thread gets exactly one state; items processed by the
        // same thread see a monotonically growing counter.
        let inits = Arc::new(AtomicU64::new(0));
        let inits2 = inits.clone();
        let out = parallel_map_with(
            (0..64).collect::<Vec<u64>>(),
            4,
            move || {
                inits2.fetch_add(1, Ordering::SeqCst);
                0u64
            },
            |seen, x| {
                *seen += 1;
                (x, *seen)
            },
        );
        // Order preserved on the item axis.
        assert_eq!(out.iter().map(|(x, _)| *x).collect::<Vec<_>>(), (0..64).collect::<Vec<_>>());
        let states = inits.load(Ordering::SeqCst);
        assert!(states >= 1 && states <= 4, "states={states}");
        // Every item incremented some thread's counter exactly once.
        let total: u64 = {
            // The max counter value per thread sums to 64 overall; since
            // we can't see thread ids, check the weaker invariant that
            // all per-item counters are >= 1 and <= 64.
            out.iter().map(|(_, c)| *c).max().unwrap()
        };
        assert!(total >= 64 / 4 && total <= 64);
        // Single-threaded: one state, counters are exactly 1..=n.
        let serial = parallel_map_with(
            vec![9, 9, 9],
            1,
            || 0u64,
            |s, _| {
                *s += 1;
                *s
            },
        );
        assert_eq!(serial, vec![1, 2, 3]);
    }

    #[test]
    fn parallel_map_sel_chunks_edges() {
        // Empty selection → no slices.
        let out: Vec<usize> = parallel_map_sel_chunks(&[], 8, 4, |s| s.len());
        assert!(out.is_empty());
        // Single row.
        let out = parallel_map_sel_chunks(&[42], 8, 4, |s| s.to_vec());
        assert_eq!(out, vec![vec![42]]);
        // chunk = 0 clamps to 1.
        let out = parallel_map_sel_chunks(&[1, 2, 3], 0, 2, |s| s.len());
        assert_eq!(out, vec![1, 1, 1]);
    }

    #[test]
    fn event_loop_fires_in_order() {
        let order = Arc::new(Mutex::new(Vec::new()));
        let mut el = EventLoop::new();
        let now = Instant::now();
        for (i, off) in [(0u32, 30u64), (1, 10), (2, 20)] {
            let order = order.clone();
            el.schedule_at(now + Duration::from_millis(off), move || {
                order.lock().unwrap().push(i);
            });
        }
        el.run();
        assert_eq!(*order.lock().unwrap(), vec![1, 2, 0]);
        assert_eq!(el.pending(), 0);
    }

    #[test]
    fn event_loop_stop_flag() {
        let mut el = EventLoop::new();
        let stop = el.stopper();
        stop.store(true, Ordering::SeqCst);
        el.schedule_after(Duration::from_millis(1), || panic!("should not fire"));
        el.run();
        assert_eq!(el.pending(), 1);
    }
}

//! Little-endian wire-format primitives shared by every codec in the
//! repo: the protocol frames ([`crate::coordinator::protocol`]) and the
//! serializable logical plans ([`crate::analytics::engine::plan`]).
//!
//! Every codec built on this module is an **exact inverse**: `encode`
//! then `decode` is the identity, decode rejects truncated input at the
//! field that runs short, and [`Reader::finish`] rejects trailing
//! garbage. Integers are little-endian; strings and byte blobs are
//! length-prefixed with a `u32`.

use crate::error::Result;

/// Bounds-checked little-endian payload reader.
pub struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, off: 0 }
    }

    /// Take the next `n` raw bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        crate::ensure!(
            n <= self.buf.len() - self.off,
            "truncated frame: need {n} bytes at offset {}, have {}",
            self.off,
            self.buf.len() - self.off
        );
        // bound: the ensure! above proves off + n <= buf.len()
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into()?))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into()?))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into()?))
    }

    pub fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into()?))
    }

    /// `u32` length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).map_err(crate::error::Error::msg)
    }

    /// `u32` length-prefixed byte blob.
    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        let len = self.u32()? as usize;
        Ok(self.take(len)?.to_vec())
    }

    /// `u32` count-prefixed vector of `u64`.
    pub fn vec_u64(&mut self) -> Result<Vec<u64>> {
        let len = self.u32()? as usize;
        (0..len).map(|_| self.u64()).collect()
    }

    /// `u32` count-prefixed vector of `u32`.
    pub fn vec_u32(&mut self) -> Result<Vec<u32>> {
        let len = self.u32()? as usize;
        (0..len).map(|_| self.u32()).collect()
    }

    /// Reject trailing garbage: every byte must have been consumed.
    pub fn finish(self) -> Result<()> {
        crate::ensure!(
            self.off == self.buf.len(),
            "trailing garbage: {} bytes past end of frame",
            self.buf.len() - self.off
        );
        Ok(())
    }
}

/// Append a `u32` length-prefixed UTF-8 string.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Append a `u32` length-prefixed byte blob.
pub fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Append a `u32` count-prefixed vector of `u64`.
pub fn put_vec_u64(out: &mut Vec<u8>, v: &[u64]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Append a `u32` count-prefixed vector of `u32`.
pub fn put_vec_u32(out: &mut Vec<u8>, v: &[u32]) {
    out.extend_from_slice(&(v.len() as u32).to_le_bytes());
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_roundtrips() {
        let mut out = Vec::new();
        out.push(7u8);
        out.extend_from_slice(&0xBEEFu16.to_le_bytes());
        out.extend_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
        out.extend_from_slice(&u64::MAX.to_le_bytes());
        out.extend_from_slice(&(-5i32).to_le_bytes());
        out.extend_from_slice(&i64::MIN.to_le_bytes());
        out.extend_from_slice(&1.5f64.to_le_bytes());
        let mut r = Reader::new(&out);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u16().unwrap(), 0xBEEF);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.i32().unwrap(), -5);
        assert_eq!(r.i64().unwrap(), i64::MIN);
        assert_eq!(r.f64().unwrap(), 1.5);
        r.finish().unwrap();
    }

    #[test]
    fn string_bytes_and_vecs_roundtrip() {
        let mut out = Vec::new();
        put_str(&mut out, "hello");
        put_bytes(&mut out, &[1, 2, 3]);
        put_vec_u64(&mut out, &[9, 10]);
        put_vec_u32(&mut out, &[7]);
        let mut r = Reader::new(&out);
        assert_eq!(r.str().unwrap(), "hello");
        assert_eq!(r.bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.vec_u64().unwrap(), vec![9, 10]);
        assert_eq!(r.vec_u32().unwrap(), vec![7]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_and_trailing_garbage_rejected() {
        let mut out = Vec::new();
        put_str(&mut out, "abc");
        assert!(Reader::new(&out[..out.len() - 1]).str().is_err());
        let mut r = Reader::new(&out);
        r.str().unwrap();
        // finish on fully-consumed input passes; an extra byte fails.
        let mut padded = out.clone();
        padded.push(0);
        let mut r2 = Reader::new(&padded);
        r2.str().unwrap();
        assert!(r2.finish().is_err());
        r.finish().unwrap();
        // A length prefix larger than the buffer is a truncation error,
        // not a huge allocation.
        let bad = u32::MAX.to_le_bytes().to_vec();
        assert!(Reader::new(&bad).bytes().is_err());
        assert!(Reader::new(&bad).vec_u64().is_err());
    }

    #[test]
    fn invalid_utf8_rejected() {
        let mut out = Vec::new();
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Reader::new(&out).str().is_err());
    }
}

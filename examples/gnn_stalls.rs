//! GNN accelerator-stall study (§5.3): sweep host NIC bandwidth, cache
//! hit rate, and Lovelock φ for the BGL workload, and cross-check the
//! analytic pipeline model against a discrete two-stage simulation of
//! fetch → compute with bounded prefetch.
//!
//! Run: `cargo run --release --example gnn_stalls`

use lovelock::gnn::{bandwidth_speedup, GnnHost, LovelockGnn};

/// Discrete-event cross-check: simulate `n` mini-batches through a fetch
/// stage (NIC) and a compute stage (GPUs) with a bounded prefetch queue;
/// returns achieved mini-batches/s.
fn simulate_pipeline(h: &GnnHost, n: usize, queue_depth: usize) -> f64 {
    let fetch_time = h.fetch_bytes_per_mb * (1.0 - h.cache_hit) / (h.nic_gbps / 8.0 * 1e9);
    let compute_time = 1.0 / h.compute_rate();
    let mut fetch_done = vec![0.0f64; n];
    let mut t_fetch = 0.0f64;
    let mut t_compute = 0.0f64;
    for i in 0..n {
        // Backpressure: fetch i can start only when slot (i - depth) was
        // consumed by compute.
        if i >= queue_depth {
            t_fetch = t_fetch.max(fetch_done[i - queue_depth]);
        }
        t_fetch += fetch_time;
        let ready = t_fetch;
        t_compute = t_compute.max(ready) + compute_time;
        fetch_done[i] = t_compute;
    }
    n as f64 / t_compute
}

fn main() {
    let base = GnnHost::bgl_server();
    let (comp, net) = (base.compute_rate(), base.network_rate());
    println!("BGL server: compute {comp:.0} mb/s, network {net:.1} mb/s");

    println!("\n-- NIC bandwidth sweep (analytic vs discrete simulation) --");
    println!("{:>10} {:>12} {:>12} {:>10}", "nic Gbps", "analytic", "simulated", "stall%");
    for gbps in [50.0, 100.0, 200.0, 400.0, 800.0] {
        let mut h = base;
        h.nic_gbps = gbps;
        let sim = simulate_pipeline(&h, 4000, 8);
        println!(
            "{:>10.0} {:>9.0} mb/s {:>9.0} mb/s {:>9.0}%",
            gbps,
            h.achieved_rate(),
            sim,
            h.stall_fraction() * 100.0
        );
        // The two models must agree within a few percent.
        assert!((sim - h.achieved_rate()).abs() / h.achieved_rate() < 0.05);
    }

    println!("\n-- Lovelock phi sweep (200G per NIC) --");
    for phi in [1u32, 2, 3, 4, 6, 8] {
        let l = LovelockGnn { phi, nic_gbps_each: 200.0, base };
        println!(
            "phi={phi}: {:>5.0} mb/s ({:.1}x vs server)",
            l.achieved_rate(),
            l.speedup_vs_server()
        );
    }

    println!("\n-- cache ablation --");
    for hit in [0.0, 0.25, 0.5, 0.75, 0.9] {
        let mut h = base;
        h.cache_hit = hit;
        println!(
            "hit={hit:.2}: {:>5.0} mb/s, GPU util {:.0}%",
            h.achieved_rate(),
            h.gpu_utilization() * 100.0
        );
    }

    println!("\n-- generic stall amortization (paper: 20% stalls, 2x bw => ~10%) --");
    for stall in [0.1, 0.2, 0.4] {
        println!(
            "stall={stall:.1}: 2x bw -> {:.3}x, 4x bw -> {:.3}x",
            bandwidth_speedup(stall, 2.0),
            bandwidth_speedup(stall, 4.0)
        );
    }
}

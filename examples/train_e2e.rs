//! End-to-end training driver — proves the full three-layer stack
//! composes: Pallas attention kernel (L1) inside the JAX transformer
//! train step (L2), AOT-compiled to HLO text and driven step by step
//! from the Rust coordinator (L3) over PJRT, Python nowhere at runtime.
//!
//! Trains the GLaM-style dense transformer on the synthetic bigram corpus
//! and logs the loss curve; host-vs-device time is accounted the way
//! Table 2 accounts host CPU, and a checkpoint (monolithic + chunked
//! stream, §5.3) is written at the end. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_e2e -- [--model 100m] [--steps 300]`

use lovelock::cli::Command;
use lovelock::configfmt::Json;
use lovelock::training::driver::TrainDriver;
use lovelock::training::hostmodel::{GlamModel, TrainSetup};
use std::time::Instant;

fn main() -> lovelock::Result<()> {
    let cmd = Command::new("train_e2e", "AOT-compiled transformer training via PJRT")
        .opt("model", Some("100m"), "model config: tiny | 100m")
        .opt("steps", Some("300"), "training steps")
        .opt("log-every", Some("10"), "loss log interval")
        .opt("seed", Some("42"), "data + init seed")
        .flag("no-checkpoint", "skip the checkpoint at the end");
    let args = match cmd.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            std::process::exit(2);
        }
    };
    let model = args.get_str("model", "100m");
    let steps = args.get_u64("steps", 300) as u32;
    let log_every = args.get_u64("log-every", 10) as u32;
    let seed = args.get_u64("seed", 42);

    let t0 = Instant::now();
    let mut driver = TrainDriver::load(&model, seed)?;
    println!(
        "model {model}: {:.1}M params ({:.0} MB packed state), batch {} x seq {}, vocab {}",
        driver.spec.params as f64 / 1e6,
        driver.spec.state_len as f64 * 4.0 / 1e6,
        driver.spec.batch,
        driver.spec.seq,
        driver.spec.vocab
    );
    driver.init(seed as i32)?;
    let init_secs = t0.elapsed().as_secs_f64();
    println!("compiled + initialized in {init_secs:.1}s; training {steps} steps…");

    let t1 = Instant::now();
    driver.run(steps, log_every)?;
    let wall = t1.elapsed().as_secs_f64();
    for (s, loss) in &driver.loss_log {
        println!("step {s:>5}  loss {loss:.4}");
    }

    let acc = driver.accounting;
    let tokens = (driver.spec.batch * driver.spec.seq) as f64 * steps as f64;
    println!(
        "\n{steps} steps in {wall:.1}s ({:.2} s/step, {:.0} tokens/s)",
        wall / steps as f64,
        tokens / wall
    );
    println!(
        "host-as-coordinator split: host {:.2}s ({:.1}%) vs device {:.2}s — the §5.3 claim",
        acc.host_secs,
        acc.host_cpu_frac() * 100.0,
        acc.device_secs
    );

    if !args.get_flag("no-checkpoint") {
        let dir = std::env::temp_dir();
        let t = Instant::now();
        let bytes = driver.checkpoint(&dir.join("lovelock_e2e_mono.ckpt"), false)?;
        let mono = t.elapsed().as_secs_f64();
        let t = Instant::now();
        driver.checkpoint(&dir.join("lovelock_e2e_chunked.ckpt"), true)?;
        let chunked = t.elapsed().as_secs_f64();
        println!(
            "checkpoint {:.0} MB: monolithic {mono:.2}s, chunked-stream {chunked:.2}s (§5.3 policy)",
            bytes as f64 / 1e6
        );
        std::fs::remove_file(dir.join("lovelock_e2e_mono.ckpt")).ok();
        std::fs::remove_file(dir.join("lovelock_e2e_chunked.ckpt")).ok();
    }

    // Compare against the analytic host model at the paper's scale.
    let setup = TrainSetup::default();
    let glam = GlamModel::glam_1b();
    let u = setup.host_usage(&glam);
    println!(
        "analytic Table-2 anchor (GLaM1B): mean host CPU {:.1}%, measured here {:.1}%",
        u.mean_cpu_frac * 100.0,
        acc.host_cpu_frac() * 100.0
    );

    // Machine-readable record.
    let losses: Vec<Json> = driver
        .loss_log
        .iter()
        .map(|(s, l)| Json::Arr(vec![Json::Num(*s as f64), Json::Num(*l as f64)]))
        .collect();
    let rec = Json::obj()
        .field("model", model.as_str())
        .field("steps", steps as u64)
        .field("wall_secs", wall)
        .field("host_frac", acc.host_cpu_frac())
        .field("loss_curve", Json::Arr(losses));
    let path = std::env::temp_dir().join("lovelock_train_e2e.json");
    std::fs::write(&path, rec.render())?;
    println!("run record: {}", path.display());

    // Success criterion: loss visibly below the starting point.
    if let (Some(first), Some(last)) = (driver.loss_log.first(), driver.loss_log.last()) {
        lovelock::ensure!(
            last.1 < first.1,
            "loss did not decrease ({} -> {})",
            first.1,
            last.1
        );
        println!("loss {:.3} -> {:.3}: OK", first.1, last.1);
    }
    Ok(())
}

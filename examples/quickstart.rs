//! Quickstart: the one-screen tour of the Lovelock library.
//!
//! Builds a traditional cluster and its Lovelock replacement, prices them
//! with the paper's cost model, runs a real TPC-H query on the analytics
//! engine (natively, and — when artifacts are built — through the
//! AOT-compiled Pallas Q6 kernel via PJRT), and projects the BigQuery
//! breakdown.
//!
//! Run: `cargo run --release --example quickstart`

use lovelock::analytics::queries::q6;
use lovelock::analytics::{run_query, TpchConfig, TpchDb};
use lovelock::bigquery::{project, Breakdown};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::costmodel::CostModel;
use lovelock::platform::n2d_milan;
use lovelock::runtime::{artifact_path, artifacts_available, literal_f32, to_f32, Engine};

fn main() -> anyhow::Result<()> {
    // 1. A cluster of 8 Milan servers, each with 4 accelerators…
    let trad = ClusterSpec::traditional(8, n2d_milan(), Role::Accelerator { count: 4 });
    // …and the Lovelock replacement: 2 IPU E2000s per server.
    let love = ClusterSpec::lovelock_e2000(&trad, 2);
    println!("traditional : {} nodes, {:5.0} Gbps aggregate, {} vcpus",
        trad.num_nodes(), trad.aggregate_nic_gbps(), trad.total_vcpus());
    println!("lovelock    : {} nodes, {:5.0} Gbps aggregate, {} vcpus",
        love.num_nodes(), love.aggregate_nic_gbps(), love.total_vcpus());
    println!("accelerators conserved: {} vs {}", trad.total_peripherals(), love.total_peripherals());

    // 2. Price it with §4's model (4-accelerator servers ⇒ PCIe share 75%).
    let m = CostModel::host_only().with_pcie_share(0.75);
    println!(
        "\ncost model (phi=2, mu=0.9): {:.2}x cheaper, {:.2}x less energy",
        m.cost_ratio(2.0),
        m.power_ratio(2.0, 0.9)
    );

    // 3. Real analytics: generate TPC-H and run Q6 on the native engine.
    let db = TpchDb::generate(TpchConfig::new(0.01, 42));
    let native = run_query(&db, "q6").unwrap();
    let revenue = native.rows[0][0].as_f64();
    println!("\nTPC-H SF 0.01: {} lineitems", db.lineitem.len());
    println!("q6 native revenue  = {revenue:.2}");

    // 4. The same query through the AOT-compiled Pallas kernel (PJRT).
    if artifacts_available() {
        let eng = Engine::cpu()?;
        let module = eng.load_module(artifact_path("q6_scan.hlo.txt"))?;
        let (ship, disc, qty, price) = q6::kernel_inputs(&db);
        let p = q6::Q6Params::default();
        let bounds = [p.date_lo as f32, p.date_hi as f32, p.disc_lo as f32, p.disc_hi as f32, p.qty_lt as f32];
        const CHUNK: usize = 65536;
        let mut total = 0f64;
        let mut off = 0;
        while off < ship.len() {
            let take = CHUNK.min(ship.len() - off);
            let mut cols = [vec![3.0e38f32; CHUNK], vec![0f32; CHUNK], vec![0f32; CHUNK], vec![0f32; CHUNK]];
            for i in 0..take {
                cols[0][i] = ship[off + i] as f32;
                cols[1][i] = disc[off + i] as f32;
                cols[2][i] = qty[off + i] as f32;
                cols[3][i] = price[off + i] as f32;
            }
            let out = module.execute(&[
                literal_f32(&cols[0], &[CHUNK as i64])?,
                literal_f32(&cols[1], &[CHUNK as i64])?,
                literal_f32(&cols[2], &[CHUNK as i64])?,
                literal_f32(&cols[3], &[CHUNK as i64])?,
                literal_f32(&bounds, &[5])?,
            ])?;
            total += to_f32(&out[0])?[0] as f64;
            off += take;
        }
        println!("q6 via PJRT kernel = {total:.2} (rel err {:.2e})",
            (total - revenue).abs() / revenue.max(1.0));
    } else {
        println!("(run `make artifacts` to also execute q6 through the Pallas kernel)");
    }

    // 5. The Fig. 4 projection.
    let b = Breakdown::isca23();
    for phi in [2.0, 3.0] {
        let pr = project(&b, phi, 4.7);
        println!("bigquery projection phi={phi}: mu = {:.2}", pr.mu());
    }
    Ok(())
}

//! Quickstart: the one-screen tour of the Lovelock library.
//!
//! Builds a traditional cluster and its Lovelock replacement, prices them
//! with the paper's cost model, runs a real TPC-H query on the analytics
//! engine (single-threaded, morsel-parallel, and distributed across the
//! simulated NIC cluster), and projects the BigQuery breakdown.
//!
//! Run: `cargo run --release --example quickstart`

use lovelock::analytics::morsel::run_query_morsel;
use lovelock::analytics::{run_query, TpchConfig, TpchDb};
use lovelock::bigquery::{project, Breakdown};
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::coordinator::DistributedQuery;
use lovelock::costmodel::CostModel;
use lovelock::platform::n2d_milan;

fn main() -> lovelock::Result<()> {
    // 1. A cluster of 8 Milan servers, each with 4 accelerators…
    let trad = ClusterSpec::traditional(8, n2d_milan(), Role::Accelerator { count: 4 });
    // …and the Lovelock replacement: 2 IPU E2000s per server.
    let love = ClusterSpec::lovelock_e2000(&trad, 2);
    println!(
        "traditional : {} nodes, {:5.0} Gbps aggregate, {} vcpus",
        trad.num_nodes(),
        trad.aggregate_nic_gbps(),
        trad.total_vcpus()
    );
    println!(
        "lovelock    : {} nodes, {:5.0} Gbps aggregate, {} vcpus",
        love.num_nodes(),
        love.aggregate_nic_gbps(),
        love.total_vcpus()
    );
    let (tp, lp) = (trad.total_peripherals(), love.total_peripherals());
    println!("accelerators conserved: {tp} vs {lp}");

    // 2. Price it with §4's model (4-accelerator servers ⇒ PCIe share 75%).
    let m = CostModel::host_only().with_pcie_share(0.75);
    println!(
        "\ncost model (phi=2, mu=0.9): {:.2}x cheaper, {:.2}x less energy",
        m.cost_ratio(2.0),
        m.power_ratio(2.0, 0.9)
    );

    // 3. Real analytics: generate TPC-H and run Q6 on the native engine,
    //    single-threaded and morsel-parallel (same rows either way).
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(0.01, 42)));
    let native = run_query(&db, "q6").unwrap();
    let revenue = native.rows[0][0].as_f64();
    println!("\nTPC-H SF 0.01: {} lineitems", db.lineitem.len());
    println!("q6 single-threaded revenue = {revenue:.2}");
    let parallel = run_query_morsel(&db, "q6", 0, 16_384).unwrap();
    assert!(parallel.approx_eq_rows(&native.rows), "morsel path diverged");
    println!("q6 morsel-parallel revenue = {:.2} (all cores)", parallel.rows[0][0].as_f64());

    // 4. The same query distributed across the simulated NIC cluster:
    //    every worker aggregates its partition, partials shuffle to the
    //    leader over the fabric simulator.
    let compute = ClusterSpec::traditional(8, n2d_milan(), Role::LiteCompute);
    let lite_love = ClusterSpec::lovelock_e2000(&compute, 2);
    for cluster in [compute, lite_love] {
        let name = cluster.name.clone();
        let r = DistributedQuery::new(cluster).run(&db, "q6")?;
        assert!(native.approx_eq_rows(&r.rows), "distributed q6 diverged");
        let (c, s, i) = r.breakdown();
        println!(
            "q6 on {name}: {} workers, sim total {:.4}s (cpu {:.0}% / shuffle {:.0}% / io {:.0}%)",
            r.workers,
            r.total_secs(),
            c * 100.0,
            s * 100.0,
            i * 100.0
        );
    }

    // 5. The Fig. 4 projection.
    let b = Breakdown::isca23();
    for phi in [2.0, 3.0] {
        let pr = project(&b, phi, 4.7);
        println!("bigquery projection phi={phi}: mu = {:.2}", pr.mu());
    }
    Ok(())
}

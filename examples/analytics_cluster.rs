//! Distributed analytics on simulated clusters — the §5.2 scenario end
//! to end: the same TPC-H shuffle jobs on a server-centric cluster and
//! its Lovelock replacements, with real per-worker compute and the
//! flow-level fabric deciding the network phases.
//!
//! Run: `cargo run --release --example analytics_cluster -- [--sf 0.02] [--workers 8]`

use lovelock::cli::Command;
use lovelock::cluster::{ClusterSpec, Role};
use lovelock::configfmt::Json;
use lovelock::coordinator::{DistributedQuery, QueryService};
use lovelock::analytics::{TpchConfig, TpchDb};
use lovelock::platform::n2d_milan;

fn main() -> lovelock::Result<()> {
    let cmd = Command::new("analytics_cluster", "distributed TPC-H: traditional vs Lovelock")
        .opt("sf", Some("0.02"), "TPC-H scale factor")
        .opt("workers", Some("8"), "server count of the traditional cluster")
        .opt("seed", Some("7"), "dbgen seed");
    let args = match cmd.parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(m) => {
            eprintln!("{m}");
            std::process::exit(2);
        }
    };
    let sf = args.get_f64("sf", 0.02);
    let workers = args.get_usize("workers", 8);
    let seed = args.get_u64("seed", 7);

    println!("generating TPC-H SF {sf} (seed {seed})…");
    let db = std::sync::Arc::new(TpchDb::generate(TpchConfig::new(sf, seed)));
    println!("{} lineitems, {} orders\n", db.lineitem.len(), db.orders.len());

    let trad = ClusterSpec::traditional(workers, n2d_milan(), Role::LiteCompute);
    let mut records = Vec::new();
    println!(
        "{:<10} {:<22} {:>9} {:>10} {:>9} {:>9} {:>8}",
        "query", "cluster", "cpu ms", "shuffle ms", "io ms", "total ms", "vs trad"
    );
    for q in ["q1", "q6", "q18"] {
        let base = DistributedQuery::new(trad.clone()).run(&db, q)?;
        let base_total = base.total_secs();
        for (label, cluster) in [
            ("traditional".to_string(), trad.clone()),
            ("lovelock phi=1".to_string(), ClusterSpec::lovelock_e2000(&trad, 1)),
            ("lovelock phi=2".to_string(), ClusterSpec::lovelock_e2000(&trad, 2)),
            ("lovelock phi=3".to_string(), ClusterSpec::lovelock_e2000(&trad, 3)),
        ] {
            let r = DistributedQuery::new(cluster).run(&db, q)?;
            println!(
                "{:<10} {:<22} {:>9.3} {:>10.3} {:>9.3} {:>9.3} {:>7.2}x",
                q,
                label,
                r.compute_secs * 1e3,
                r.shuffle_secs * 1e3,
                r.io_secs * 1e3,
                r.total_secs() * 1e3,
                base_total / r.total_secs()
            );
            records.push(
                Json::obj()
                    .field("query", q)
                    .field("cluster", label.as_str())
                    .field("compute_secs", r.compute_secs)
                    .field("shuffle_secs", r.shuffle_secs)
                    .field("io_secs", r.io_secs)
                    .field("rows", r.rows.len())
                    .field("exchange_bytes", r.exchange_bytes)
                    .field("shuffle_bytes", r.shuffle_bytes),
            );
        }
        println!();
    }
    // The session API: submit the whole query set at once and let the
    // queries interleave over one service's shared scheduler, credits,
    // and worker endpoints (frames of different queries mix on the wire).
    let svc = QueryService::new(trad.clone());
    let t0 = std::time::Instant::now();
    let batch = ["q1", "q6", "q18", "q3"];
    let ids: Vec<_> = batch
        .iter()
        .map(|q| svc.submit(&db, q))
        .collect::<lovelock::Result<_>>()?;
    println!("submitted {} concurrent queries:", batch.len());
    for (q, id) in batch.iter().zip(ids) {
        let (rows, r) = svc.wait(id)?;
        println!(
            "  {id} {q}: {} rows, {} KB exchanged, {} B control frames",
            rows.len(),
            r.exchange_bytes / 1000,
            r.control_bytes
        );
    }
    println!(
        "batch wall time {:.1} ms ({:.1} queries/s)\n",
        t0.elapsed().as_secs_f64() * 1e3,
        batch.len() as f64 / t0.elapsed().as_secs_f64()
    );

    // Machine-readable run record.
    let record = Json::obj()
        .field("sf", sf)
        .field("workers", workers)
        .field("runs", Json::Arr(records));
    let path = std::env::temp_dir().join("lovelock_analytics_cluster.json");
    std::fs::write(&path, record.render())?;
    println!("run record: {}", path.display());
    Ok(())
}

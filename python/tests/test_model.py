"""L2 model tests: shapes, packing, training dynamics, AOT contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


CFG = model.TINY


def test_param_count_formula():
    # embed + per-layer (2 LN + qkv + wo + 2 mlp) + final LN.
    d, ff, v = CFG.d_model, CFG.ff, CFG.vocab
    per_layer = 2 * d + d * 3 * d + d * d + d * ff + ff * d
    want = v * d + CFG.n_layers * per_layer + d
    assert model.num_params(CFG) == want
    assert model.state_len(CFG) == 2 + 3 * want


def test_pack_unpack_roundtrip():
    flat = jnp.arange(model.num_params(CFG), dtype=jnp.float32)
    params = model.unpack(CFG, flat)
    assert set(params) == set(model.param_shapes(CFG))
    flat2 = model.pack(CFG, params)
    np.testing.assert_array_equal(flat, flat2)


def test_forward_shapes():
    init = model.make_init(CFG)
    state = init(jnp.array([0], jnp.int32))
    params = model.unpack(CFG, state[2 : 2 + model.num_params(CFG)])
    tokens = jnp.zeros((CFG.batch, CFG.seq), jnp.int32)
    logits = model.forward(CFG, params, tokens)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)


def test_initial_loss_near_uniform():
    init = model.make_init(CFG)
    state = init(jnp.array([1], jnp.int32))
    params = model.unpack(CFG, state[2 : 2 + model.num_params(CFG)])
    rs = np.random.RandomState(0)
    toks = jnp.array(rs.randint(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)
    loss = model.loss_fn(CFG, params, toks)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_train_step_decreases_loss():
    init = jax.jit(model.make_init(CFG))
    step = jax.jit(model.make_train_step(CFG))
    state = init(jnp.array([42], jnp.int32))
    rs = np.random.RandomState(1)
    # Repeated batch → loss must fall fast.
    toks = jnp.array(rs.randint(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)
    first = None
    for i in range(10):
        state = step(state, toks)
        if first is None:
            first = float(state[0])
    assert float(state[1]) == 10.0  # step counter
    assert float(state[0]) < first - 0.5, (first, float(state[0]))


def test_state_layout_slots():
    init = jax.jit(model.make_init(CFG))
    state = init(jnp.array([7], jnp.int32))
    assert state.shape == (model.state_len(CFG),)
    assert float(state[0]) == 0.0  # loss slot
    assert float(state[1]) == 0.0  # step slot
    p = model.num_params(CFG)
    # adam m/v start at zero
    assert float(jnp.abs(state[2 + p :]).max()) == 0.0
    # params are not all zero
    assert float(jnp.abs(state[2 : 2 + p]).max()) > 0.0


def test_init_seed_changes_params():
    init = jax.jit(model.make_init(CFG))
    a = init(jnp.array([1], jnp.int32))
    b = init(jnp.array([2], jnp.int32))
    assert not np.allclose(np.asarray(a[2:100]), np.asarray(b[2:100]))


def test_eval_loss_matches_train_loss_pre_update():
    init = jax.jit(model.make_init(CFG))
    step = jax.jit(model.make_train_step(CFG))
    ev = jax.jit(model.make_eval_loss(CFG))
    state = init(jnp.array([3], jnp.int32))
    rs = np.random.RandomState(2)
    toks = jnp.array(rs.randint(0, CFG.vocab, (CFG.batch, CFG.seq + 1)), jnp.int32)
    loss_eval = float(ev(state, toks)[0])
    new_state = step(state, toks)
    # train_step records the loss of the *pre-update* parameters.
    assert abs(float(new_state[0]) - loss_eval) < 1e-4


def test_configs_registered():
    assert "tiny" in model.CONFIGS and "100m" in model.CONFIGS
    big = model.CONFIGS["100m"]
    # The E2E config really is ~100M parameters.
    assert 80e6 < model.num_params(big) < 120e6

"""Kernel vs reference: the core L1 correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, matmul, q6_scan, ref


def rand(shape, seed, scale=1.0):
    return (scale * np.random.RandomState(seed).randn(*shape)).astype(np.float32)


class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n", [(128, 128, 128), (256, 512, 384), (128, 256, 128), (512, 128, 256)]
    )
    def test_matches_ref(self, m, k, n):
        x, y = rand((m, k), 0), rand((k, n), 1)
        got = matmul.matmul(x, y)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-5, atol=2e-4)

    def test_small_blocks(self):
        x, y = rand((64, 64), 2), rand((64, 64), 3)
        got = matmul.matmul(x, y, bm=32, bn=32, bk=16)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=2e-5, atol=2e-4)

    def test_rejects_untileable(self):
        with pytest.raises(AssertionError):
            matmul.matmul(rand((100, 128), 0), rand((128, 128), 1), bm=64)

    def test_identity(self):
        x = rand((128, 128), 4)
        eye = np.eye(128, dtype=np.float32)
        np.testing.assert_allclose(matmul.matmul(x, eye), x, rtol=1e-6, atol=1e-5)

    def test_vmem_budget(self):
        # Default tiles must fit comfortably in 16 MiB VMEM.
        assert matmul.vmem_bytes() < 4 << 20


class TestAttention:
    @pytest.mark.parametrize("b,h,s,d", [(1, 1, 64, 32), (2, 4, 128, 64), (1, 2, 256, 64)])
    def test_causal_matches_ref(self, b, h, s, d):
        q, k, v = rand((b, h, s, d), 0, 0.5), rand((b, h, s, d), 1, 0.5), rand((b, h, s, d), 2)
        got = attention.attention(q, k, v)
        want = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        q = rand((1, 2, 64, 32), 3, 0.5)
        got = attention.attention(q, q, q, 32, 32, False)
        want = ref.attention_ref(q, q, q, causal=False)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_block_size_invariance(self):
        q = rand((1, 2, 128, 32), 4, 0.5)
        a = attention.attention(q, q, q, 32, 32, True)
        b = attention.attention(q, q, q, 64, 128, True)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_gradients_flow(self):
        # custom-vjp backward (reference vjp) must match autodiff of ref.
        q = rand((1, 2, 64, 32), 5, 0.3)

        def loss_kernel(x):
            return attention.attention(x, x, x).sum()

        def loss_ref(x):
            return ref.attention_ref(x, x, x, causal=True).sum()

        gk = jax.grad(loss_kernel)(q)
        gr = jax.grad(loss_ref)(q)
        np.testing.assert_allclose(gk, gr, rtol=5e-4, atol=5e-4)

    def test_first_row_attends_self_only(self):
        # Causality: output row 0 must equal v row 0.
        q, k = rand((1, 1, 64, 16), 6), rand((1, 1, 64, 16), 7)
        v = rand((1, 1, 64, 16), 8)
        out = attention.attention(q, k, v)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], rtol=1e-5, atol=1e-6)


class TestQ6:
    def cols(self, n, seed=0):
        rs = np.random.RandomState(seed)
        ship = rs.uniform(8000, 9000, n).astype(np.float32)
        disc = (rs.randint(0, 11, n) / 100.0).astype(np.float32)
        qty = rs.randint(1, 51, n).astype(np.float32)
        price = rs.uniform(100, 10000, n).astype(np.float32)
        return ship, disc, qty, price

    def bounds(self):
        return np.array([8300, 8600, 0.045, 0.075, 24.0], np.float32)

    @pytest.mark.parametrize("n", [8192, 65536])
    def test_matches_ref(self, n):
        cols = self.cols(n)
        got = q6_scan.q6_scan(*cols, self.bounds())
        want = ref.q6_ref(*cols, self.bounds())
        np.testing.assert_allclose(got[0], want, rtol=1e-4)

    def test_empty_window(self):
        cols = self.cols(8192, 1)
        b = np.array([0, 1, 0.045, 0.075, 24.0], np.float32)
        assert float(q6_scan.q6_scan(*cols, b)[0]) == 0.0

    def test_block_invariance(self):
        cols = self.cols(65536, 2)
        a = q6_scan.q6_scan(*cols, self.bounds(), block=8192)
        b = q6_scan.q6_scan(*cols, self.bounds(), block=65536)
        np.testing.assert_allclose(a, b, rtol=1e-5)

    def test_padding_convention(self):
        # The Rust caller pads with shipdate=+inf; padded rows contribute 0.
        cols = list(self.cols(8192, 3))
        padded = [np.concatenate([c, np.zeros(8192, np.float32)]) for c in cols]
        padded[0][8192:] = np.float32(3.0e38)  # shipdate fails every filter
        a = q6_scan.q6_scan(*cols, self.bounds())
        b = q6_scan.q6_scan(*padded, self.bounds())
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_vmem_budget(self):
        assert q6_scan.vmem_bytes() < 1 << 20

"""Hypothesis sweeps over kernel shapes/values vs the references."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, matmul, q6_scan, ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def matmul_shapes(draw):
    bm = draw(st.sampled_from([16, 32, 64]))
    bk = draw(st.sampled_from([16, 32, 64]))
    bn = draw(st.sampled_from([16, 32, 64]))
    m = bm * draw(st.integers(1, 4))
    k = bk * draw(st.integers(1, 4))
    n = bn * draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2**31 - 1))
    return m, k, n, bm, bk, bn, seed


@given(matmul_shapes())
@settings(**SETTINGS)
def test_matmul_shape_sweep(shape):
    m, k, n, bm, bk, bn, seed = shape
    rs = np.random.RandomState(seed)
    x = rs.randn(m, k).astype(np.float32)
    y = rs.randn(k, n).astype(np.float32)
    got = matmul.matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-3)


@st.composite
def attn_shapes(draw):
    b = draw(st.integers(1, 2))
    h = draw(st.sampled_from([1, 2, 4]))
    s = draw(st.sampled_from([32, 64, 128]))
    d = draw(st.sampled_from([16, 32, 64]))
    seed = draw(st.integers(0, 2**31 - 1))
    causal = draw(st.booleans())
    return b, h, s, d, seed, causal


@given(attn_shapes())
@settings(**SETTINGS)
def test_attention_shape_sweep(shape):
    b, h, s, d, seed, causal = shape
    rs = np.random.RandomState(seed)
    q = (0.5 * rs.randn(b, h, s, d)).astype(np.float32)
    k = (0.5 * rs.randn(b, h, s, d)).astype(np.float32)
    v = rs.randn(b, h, s, d).astype(np.float32)
    got = attention.attention(q, k, v, min(32, s), min(32, s), causal)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


@st.composite
def q6_case(draw):
    n = draw(st.sampled_from([4096, 8192, 16384]))
    seed = draw(st.integers(0, 2**31 - 1))
    lo = draw(st.floats(8000, 8500))
    width = draw(st.floats(10, 500))
    qty_lt = draw(st.floats(1, 50))
    return n, seed, lo, lo + width, qty_lt


@given(q6_case())
@settings(**SETTINGS)
def test_q6_bounds_sweep(case):
    n, seed, lo, hi, qty_lt = case
    rs = np.random.RandomState(seed)
    ship = rs.uniform(7900, 8600, n).astype(np.float32)
    disc = (rs.randint(0, 11, n) / 100.0).astype(np.float32)
    qty = rs.randint(1, 51, n).astype(np.float32)
    price = rs.uniform(1, 1000, n).astype(np.float32)
    bounds = np.array([lo, hi, 0.045, 0.075, qty_lt], np.float32)
    got = float(q6_scan.q6_scan(ship, disc, qty, price, bounds, block=4096)[0])
    want = float(ref.q6_ref(ship, disc, qty, price, bounds))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)

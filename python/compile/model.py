"""L2: GLaM-style dense decoder-only transformer, packed-state training.

This is the §5.3 workload — the model the accelerators run while the
(smart-NIC) host merely coordinates. The whole training state lives in a
single flat f32 vector so the Rust driver can hold it as one device
buffer and feed each step's output straight back in (no per-step host
round-trips):

    state = [ loss, step, theta (P), adam_m (P), adam_v (P) ]   # f32[2+3P]

Exported entry points (AOT-lowered by ``aot.py``):

* ``make_init(cfg)``   — ``(seed i32[1]) -> f32[2+3P]``
* ``make_train_step(cfg)`` — ``(state f32[2+3P], tokens i32[B,S+1]) ->
  f32[2+3P]`` — one AdamW step of next-token cross-entropy; the new
  loss is written into slot 0.

Attention runs through the Pallas flash kernel
(``kernels.attention.attention``) so the lowered HLO carries the L1
kernel on its forward path.
"""

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from .kernels.attention import attention


@dataclasses.dataclass(frozen=True)
class Config:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq: int
    batch: int
    d_ff: int = 0  # 0 → 4·d_model
    lr: float = 1e-3
    wd: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.98
    eps: float = 1e-8

    @property
    def ff(self) -> int:
        return self.d_ff or 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


TINY = Config(name="tiny", vocab=512, d_model=64, n_layers=2, n_heads=4, seq=64, batch=8)
GLAM_100M = Config(
    name="100m", vocab=8192, d_model=768, n_layers=12, n_heads=12, seq=128, batch=2
)

CONFIGS = {c.name: c for c in (TINY, GLAM_100M)}


# ------------------------------------------------------------- parameters

def param_shapes(cfg: Config) -> Dict[str, tuple]:
    """Ordered parameter dictionary (order defines the packing layout)."""
    shapes = {"embed": (cfg.vocab, cfg.d_model)}
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        shapes[p + "ln1"] = (cfg.d_model,)
        shapes[p + "wqkv"] = (cfg.d_model, 3 * cfg.d_model)
        shapes[p + "wo"] = (cfg.d_model, cfg.d_model)
        shapes[p + "ln2"] = (cfg.d_model,)
        shapes[p + "w1"] = (cfg.d_model, cfg.ff)
        shapes[p + "w2"] = (cfg.ff, cfg.d_model)
    shapes["ln_f"] = (cfg.d_model,)
    return shapes


def num_params(cfg: Config) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for s in param_shapes(cfg).values())


def state_len(cfg: Config) -> int:
    return 2 + 3 * num_params(cfg)


def unpack(cfg: Config, flat):
    """Flat parameter vector → dict of arrays."""
    params = {}
    off = 0
    for name, shape in param_shapes(cfg).items():
        n = 1
        for s in shape:
            n *= s
        params[name] = flat[off : off + n].reshape(shape)
        off += n
    return params


def pack(cfg: Config, params) -> jnp.ndarray:
    return jnp.concatenate(
        [params[name].reshape(-1) for name in param_shapes(cfg)]
    )


# ------------------------------------------------------------------ model

def _layernorm(x, gain):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * gain


def forward(cfg: Config, params, tokens):
    """Logits for tokens [B, S] → [B, S, vocab]. Tied embeddings."""
    x = params["embed"][tokens]  # [B, S, D]
    b, s, d = x.shape
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        h = _layernorm(x, params[p + "ln1"])
        qkv = h @ params[p + "wqkv"]  # [B, S, 3D]
        q, k, v = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

        # Pallas flash-attention kernel; whole-sequence tiles (bq = bk =
        # S up to 128) keep the interpret-mode grid minimal and the VMEM
        # estimate at S·D·4B per slab — see EXPERIMENTS.md §Perf L1.
        bs = min(128, s)
        o = attention(heads(q), heads(k), heads(v), bs, bs)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
        x = x + o @ params[p + "wo"]
        h = _layernorm(x, params[p + "ln2"])
        x = x + jax.nn.gelu(h @ params[p + "w1"]) @ params[p + "w2"]
    x = _layernorm(x, params["ln_f"])
    return x @ params["embed"].T


def loss_fn(cfg: Config, params, tokens):
    """Mean next-token cross-entropy; tokens [B, S+1]."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


# --------------------------------------------------------------- training

def make_init(cfg: Config):
    """(seed i32[1]) -> packed state f32[2+3P]."""

    def init(seed):
        key = jax.random.PRNGKey(seed[0])
        shapes = param_shapes(cfg)
        keys = jax.random.split(key, len(shapes))
        params = {}
        for (name, shape), k in zip(shapes.items(), keys):
            if len(shape) == 1:
                params[name] = jnp.ones(shape, jnp.float32)  # LN gains
            else:
                fan_in = shape[0]
                std = (1.0 / fan_in) ** 0.5
                params[name] = std * jax.random.normal(k, shape, jnp.float32)
        theta = pack(cfg, params)
        zeros = jnp.zeros_like(theta)
        head = jnp.array([0.0, 0.0], jnp.float32)  # loss, step
        return jnp.concatenate([head, theta, zeros, zeros])

    return init


def make_train_step(cfg: Config):
    """(state f32[2+3P], tokens i32[B,S+1]) -> state f32[2+3P]."""
    p = num_params(cfg)

    def step(state, tokens):
        t = state[1] + 1.0
        theta = state[2 : 2 + p]
        m = state[2 + p : 2 + 2 * p]
        v = state[2 + 2 * p : 2 + 3 * p]

        params = unpack(cfg, theta)
        loss, grads = jax.value_and_grad(lambda q: loss_fn(cfg, q, tokens))(params)
        g = pack(cfg, grads)

        m = cfg.beta1 * m + (1.0 - cfg.beta1) * g
        v = cfg.beta2 * v + (1.0 - cfg.beta2) * g * g
        m_hat = m / (1.0 - cfg.beta1**t)
        v_hat = v / (1.0 - cfg.beta2**t)
        theta = theta - cfg.lr * (
            m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.wd * theta
        )
        head = jnp.stack([loss, t])
        return jnp.concatenate([head, theta, m, v])

    return step


def make_eval_loss(cfg: Config):
    """(state f32[2+3P], tokens i32[B,S+1]) -> f32[1] loss (no update)."""
    p = num_params(cfg)

    def eval_loss(state, tokens):
        params = unpack(cfg, state[2 : 2 + p])
        return jnp.stack([loss_fn(cfg, params, tokens)])

    return eval_loss

"""Flash-attention-style fused attention Pallas kernel.

Hardware adaptation: the GPU flash-attention papers tile Q into
threadblocks and stream K/V through shared memory with an online softmax.
On TPU the same insight maps to: one Q block resident in VMEM per grid
step, K/V streamed block-by-block with the running (max, denominator)
carried in registers/VMEM scratch, block matmuls on the MXU. Here the
K/V stream is a ``fori_loop`` over blocks of the full-sequence K/V slabs
(S·D f32 at our sizes is tens of KiB — comfortably VMEM-resident), which
is the right shape for short-context models like ours.

``interpret=True`` everywhere: real-TPU lowering emits Mosaic custom
calls the CPU PJRT plugin cannot run.

The public ``attention`` wrapper is a ``jax.custom_vjp``: forward runs
this kernel, backward differentiates the jnp reference — so the AOT
training step keeps the kernel on its forward path while remaining
differentiable (the standard recipe when no hand-written backward kernel
is provided).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, causal, scale):
    # Block shapes: q_ref [1, bq, D]; k_ref/v_ref [1, S, D]; o_ref [1, bq, D].
    qi = pl.program_id(1)
    q = q_ref[0, :, :] * scale  # [bq, d]
    s_total = k_ref.shape[1]
    d = q.shape[-1]
    nblocks = s_total // bk

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    def body(kb, carry):
        acc, m_run, l_run = carry
        k_blk = jax.lax.dynamic_slice(k_ref[0, :, :], (kb * bk, 0), (bk, d))
        v_blk = jax.lax.dynamic_slice(v_ref[0, :, :], (kb * bk, 0), (bk, d))
        logits = jnp.dot(q, k_blk.T, preferred_element_type=jnp.float32)
        if causal:
            k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
        m_new = jnp.maximum(m_run, logits.max(axis=-1))
        correction = jnp.exp(m_run - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_run * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + jnp.dot(
            p, v_blk, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, _, l_run = jax.lax.fori_loop(0, nblocks, body, (acc0, m0, l0))
    o_ref[0, :, :] = acc / l_run[:, None]


def _attention_fwd_pallas(q, k, v, *, bq, bk, causal):
    b, h, s, d = q.shape
    bq = min(bq, s)
    bk = min(bk, s)
    assert s % bq == 0 and s % bk == 0, f"S={s} must tile by ({bq},{bk})"
    scale = 1.0 / (d**0.5)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    kernel = functools.partial(
        _attention_kernel, bq=bq, bk=bk, causal=causal, scale=scale
    )
    out = pl.pallas_call(
        kernel,
        grid=(b * h, s // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def attention(q, k, v, bq=64, bk=64, causal=True):
    """Fused attention: Pallas forward, reference-vjp backward."""
    return _attention_fwd_pallas(q, k, v, bq=bq, bk=bk, causal=causal)


def _fwd(q, k, v, bq, bk, causal):
    out = _attention_fwd_pallas(q, k, v, bq=bq, bk=bk, causal=causal)
    return out, (q, k, v)


def _bwd(bq, bk, causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)


def vmem_bytes(bq=64, bk=64, s=128, d=64, dtype_bytes=4):
    """Estimated VMEM per grid step: Q block + K/V slabs + accumulators."""
    return (bq * d + 2 * s * d + 2 * bq * d + 2 * bq) * dtype_bytes

"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has its numerics checked against these
references by ``python/tests`` (exact structure, loose float tolerance).
The references are also used as the backward pass of the custom-vjp
wrappers (forward = Pallas kernel, backward = vjp of the reference),
which keeps the AOT-lowered training step differentiable while the
forward compute path goes through the kernels.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Plain matmul, f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def attention_ref(q, k, v, *, causal=True):
    """Scaled dot-product attention over [B, H, S, D] tensors."""
    d = q.shape[-1]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(d))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask[None, None, :, :], logits, -1e30)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def q6_ref(shipdate, discount, quantity, extprice, bounds):
    """TPC-H Q6 revenue: sum(extprice*discount) under the filters.

    ``bounds`` = [date_lo, date_hi, disc_lo, disc_hi, qty_lt] (f32[5]).
    The date window is half-open [lo, hi), the discount window half-open
    [lo, hi), quantity strictly less-than — matching the Rust engine.
    """
    date_lo, date_hi, disc_lo, disc_hi, qty_lt = (bounds[i] for i in range(5))
    mask = (
        (shipdate >= date_lo)
        & (shipdate < date_hi)
        & (discount >= disc_lo)
        & (discount < disc_hi)
        & (quantity < qty_lt)
    )
    return jnp.sum(jnp.where(mask, extprice * discount, 0.0))

"""L1: Pallas kernels for the Lovelock compute hot-spots.

``matmul`` — MXU-tiled matrix multiply; ``attention`` — fused
flash-attention forward (custom-vjp backward via the reference);
``q6_scan`` — the TPC-H Q6 scan-aggregate offload. ``ref`` holds the
pure-jnp oracles that pytest checks every kernel against.
"""

from . import attention, matmul, q6_scan, ref  # noqa: F401

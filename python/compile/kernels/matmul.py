"""Tiled matmul Pallas kernel.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): on a real TPU this
kernel drives the MXU with (bm x bk)·(bk x bn) tiles resident in VMEM and
an output tile revisited across the K grid axis (the accumulation axis is
innermost so the output block stays hot). On this CPU image it must run
with ``interpret=True`` — real TPU lowering emits a Mosaic custom-call the
CPU PJRT plugin cannot execute.

VMEM budget at the default blocks (bm=bn=128, bk=128, f32):
3 tiles x 128·128·4 B = 192 KiB « 16 MiB VMEM; MXU utilization estimate:
128-multiples feed the 128x128 systolic array at full occupancy.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref):
    # Zero the output tile on the first K step, then accumulate partial
    # products as the K grid axis revisits the same output block.
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=128, bn=128, bk=128):
    """``x @ y`` via the tiled Pallas kernel (interpret mode on CPU).

    Shapes must tile evenly: M % bm == K % bk == N % bn == 0.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"({m},{k})x({k},{n}) does not tile by ({bm},{bn},{bk})"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def vmem_bytes(bm=128, bn=128, bk=128, dtype_bytes=4):
    """Estimated VMEM footprint of one grid step (perf model input)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes

"""TPC-H Q6 scan-aggregate Pallas kernel — the analytics offload path.

The paper singles out Q6 as the compute-bound scan in Figure 3; this
kernel is the Lovelock "data processing accelerator" version of it
(§6): a single fused pass of filter + multiply + reduce over columnar
inputs, blocked along the row axis so each grid step streams one
VMEM-resident tile per column and accumulates a scalar partial.

On TPU the 8192-row f32 tiles (4 columns x 32 KiB) stream HBM→VMEM at
memory speed and reduce on the VPU; on this CPU image it runs under
``interpret=True``. The Rust engine executes the AOT artifact of this
kernel via PJRT as an alternative Q6 backend (``runtime`` +
``examples/quickstart.rs``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed chunk the AOT artifact is compiled for; the Rust caller pads the
# last chunk (shipdate = +inf fails every filter).
CHUNK = 65536
BLOCK = 8192


def _q6_kernel(ship_ref, disc_ref, qty_ref, price_ref, bounds_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ship = ship_ref[...]
    disc = disc_ref[...]
    qty = qty_ref[...]
    price = price_ref[...]
    b = bounds_ref[...]
    mask = (
        (ship >= b[0])
        & (ship < b[1])
        & (disc >= b[2])
        & (disc < b[3])
        & (qty < b[4])
    )
    o_ref[...] += jnp.sum(jnp.where(mask, price * disc, 0.0))


@functools.partial(jax.jit, static_argnames=("block",))
def q6_scan(shipdate, discount, quantity, extprice, bounds, *, block=BLOCK):
    """Fused Q6 revenue over equal-length f32 columns.

    ``bounds`` = f32[5]: [date_lo, date_hi, disc_lo, disc_hi, qty_lt].
    Length must tile by ``block``.
    """
    (n,) = shipdate.shape
    block = min(block, n)
    assert n % block == 0, f"n={n} must tile by block={block}"
    grid = (n // block,)
    col = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _q6_kernel,
        grid=grid,
        in_specs=[col, col, col, col, pl.BlockSpec((5,), lambda i: (0,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(shipdate, discount, quantity, extprice, bounds)


def vmem_bytes(block=BLOCK, dtype_bytes=4):
    """Estimated VMEM per grid step: 4 column tiles + bounds + scalar."""
    return (4 * block + 5 + 1) * dtype_bytes

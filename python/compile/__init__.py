"""Build-time compile path: JAX model (L2) + Pallas kernels (L1) + AOT.

Nothing here runs on the request path — ``aot.py`` lowers everything to
HLO text under ``artifacts/`` once, and the Rust runtime loads those.
"""
